"""Sharding rules, data determinism, checkpoint store, hloparse units."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.data import (CaptionProxyConfig, CaptionProxyDataset,
                        MarkovLMConfig, MarkovLMDataset, ShardedLoader)
from repro.launch import hloparse
from repro.launch.mesh import (make_abstract_mesh, make_host_mesh,
                              set_mesh)
from repro.models.registry import build_model
from repro.parallel.sharding import (batch_shardings, default_rules,
                                     spec_for, tree_shardings)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_spec_divisibility_fallback():
    # abstract 16x16 production mesh: no devices needed for spec logic
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    rules = {"heads": "model", "embed": "data", "kv": "model"}
    # divisible dims shard
    assert spec_for(("embed", "heads"), (64, 64), rules, mesh) == \
        P("data", "model")
    # 14 q-heads don't divide 16 -> that dim replicates (qwen2 case)
    assert spec_for(("embed", "heads"), (64, 14), rules, mesh) == P("data")
    # kv=1 (granite MQA) can't shard either
    assert spec_for(("kv",), (1,), rules, mesh) == P()


def test_tree_shardings_cover_params():
    cfg = get_smoke("kimi-k2-1t-a32b")
    model = build_model(cfg)
    mesh = make_host_mesh()
    shardings = tree_shardings(model.logical_axes(), model.param_structs(),
                               default_rules(cfg), mesh)
    n_params = len(jax.tree_util.tree_leaves(model.param_structs()))
    n_shard = len(jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_shard


def test_jit_with_shardings_runs():
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    mesh = make_host_mesh()
    rules = default_rules(cfg)
    p_sh = tree_shardings(model.logical_axes(), model.param_structs(),
                          rules, mesh)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    b_sh = batch_shardings(
        {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
         for k, v in batch.items()}, rules, mesh)
    with set_mesh(mesh):
        params = jax.jit(model.init, out_shardings=p_sh)(
            jax.random.PRNGKey(0))
        loss = jax.jit(model.loss, in_shardings=(p_sh, b_sh))(params, batch)
    assert jnp.isfinite(loss)


# ---------------------------------------------------------------------------
# data determinism
# ---------------------------------------------------------------------------

def test_markov_deterministic_per_step():
    cfg = MarkovLMConfig(vocab_size=128, seq_len=16, batch_size=4)
    a, b = MarkovLMDataset(cfg), MarkovLMDataset(cfg)
    for step in (0, 3, 17):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                      b.batch_at(step)["tokens"])
    # distinct steps differ
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              a.batch_at(1)["tokens"])


def test_markov_labels_shifted_and_learnable():
    cfg = MarkovLMConfig(vocab_size=64, seq_len=32, batch_size=4,
                         branching=2)
    ds = MarkovLMDataset(cfg)
    b = ds.batch_at(0)
    # label t must be a valid successor of token t in the chain
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            assert l in ds.table[t]


def test_markov_hosts_get_different_data():
    cfg = MarkovLMConfig(vocab_size=128, seq_len=16, batch_size=4)
    a = MarkovLMDataset(cfg, host_id=0, num_hosts=2)
    b = MarkovLMDataset(cfg, host_id=1, num_hosts=2)
    assert not np.array_equal(a.batch_at(5)["tokens"],
                              b.batch_at(5)["tokens"])


def test_loader_seek_resumes_stream():
    cfg = MarkovLMConfig(vocab_size=128, seq_len=16, batch_size=2)
    ds = MarkovLMDataset(cfg)
    l1 = ShardedLoader(ds)
    seen = [next(l1)["tokens"] for _ in range(5)]
    l2 = ShardedLoader(ds)
    l2.seek(3)
    np.testing.assert_array_equal(np.asarray(next(l2)["tokens"]),
                                  np.asarray(seen[3]))


def test_caption_proxy_references_stable():
    cfg = CaptionProxyConfig(vocab_size=256, seq_len=8, d_model=16,
                             n_vis=4, batch_size=4, n_images=32)
    ds = CaptionProxyDataset(cfg)
    b = ds.batch_at(0)
    refs = ds.references(b["image_id"])
    assert refs.shape == (4, 8)
    # ~90% of caption labels match the reference (10% injected noise)
    match = (b["labels"] == refs).mean()
    assert 0.7 < match <= 1.0
    # teacher-forcing shift: tokens = [BOS, labels[:-1]]
    assert (b["tokens"][:, 0] == 0).all()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# hloparse units
# ---------------------------------------------------------------------------

def test_hloparse_counts_plain_matmul():
    m, k, n = 64, 32, 16

    def f(a, b):
        return a @ b

    lowered = jax.jit(f).lower(jnp.zeros((m, k)), jnp.zeros((k, n)))
    costs = hloparse.analyze(lowered.compile().as_text())
    assert costs.flops == pytest.approx(2 * m * k * n, rel=1e-6)


def test_hloparse_multiplies_scan_bodies():
    def f(x, ws):
        def step(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(step, x, ws)
        return x

    L, d = 8, 16
    lowered = jax.jit(f).lower(jnp.zeros((4, d)), jnp.zeros((L, d, d)))
    costs = hloparse.analyze(lowered.compile().as_text())
    assert costs.n_while >= 1
    assert max(costs.trip_counts) == L
    assert costs.flops == pytest.approx(L * 2 * 4 * d * d, rel=0.01)


def test_hloparse_shape_bytes():
    assert hloparse._shape_bytes("f32[4,8]{1,0}") == 128
    assert hloparse._shape_bytes("bf16[10]") == 20
    assert hloparse._shape_bytes("(f32[2], s8[4])") == 12
    assert hloparse._shape_bytes("pred[]") == 1
