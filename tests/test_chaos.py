"""Chaos engineering (DESIGN.md §15): seeded fault traces, the spec
parser, the payload checksum, and the ServingSupervisor's defenses
(retry, retransmit, shed, device-only failover, fleet reallocation).

The decode-engine crash/recovery parity matrix lives in
``test_fault_tolerance.py`` next to the other restart-style tests.
"""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.env import (AgentDropout, ChaosTrace, LinkOutage,
                       PacketCorruption, ServerPreemption, chaos_from_spec)
from repro.env.presets import chaos_clean, chaos_storm
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, FleetAgentSpec,
                           FleetCoInferenceEngine, QosClass,
                           ServingSupervisor, flip_bit, payload_checksum)

# ---------------------------------------------------------------------------
# fault traces: determinism, stationarity, clamping
# ---------------------------------------------------------------------------

def _outage_trace(seed, p_fail=0.1, p_recover=0.3, n=400):
    return ChaosTrace(dt_s=1.0, horizon_s=float(n), seed=seed,
                      link_outage=LinkOutage(p_fail=p_fail,
                                             p_recover=p_recover))


def test_same_seed_same_schedule():
    a, b = _outage_trace(7), _outage_trace(7)
    np.testing.assert_array_equal(a.link_up, b.link_up)
    np.testing.assert_array_equal(a.server_up, b.server_up)
    c = _outage_trace(8)
    assert not np.array_equal(a.link_up, c.link_up)


def test_adding_a_process_never_reshuffles_the_others():
    # child rng streams are spawned in a fixed order, so composing a
    # preemption process on top must not change the link schedule
    a = _outage_trace(3)
    b = ChaosTrace(dt_s=1.0, horizon_s=400.0, seed=3,
                   link_outage=LinkOutage(p_fail=0.1, p_recover=0.3),
                   preemption=ServerPreemption(mtbf_s=10.0, mttr_s=5.0))
    np.testing.assert_array_equal(a.link_up, b.link_up)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**31 - 1),
       p_fail=st.floats(0.05, 0.5),
       p_recover=st.floats(0.05, 0.5))
def test_outage_fraction_matches_stationary_rate(seed, p_fail, p_recover):
    # the Markov chain's stationary down-fraction is
    # p_fail / (p_fail + p_recover); a long trace should be close
    tr = _outage_trace(seed, p_fail, p_recover, n=6000)
    want = p_fail / (p_fail + p_recover)
    assert abs(tr.outage_fraction() - want) < 0.12


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**31 - 1))
def test_trace_is_pure_function_of_seed(seed):
    kw = dict(dt_s=0.25, horizon_s=50.0, seed=seed, n_agents=2,
              link_outage=LinkOutage(0.2, 0.2),
              corruption=PacketCorruption(0.1),
              preemption=ServerPreemption(mtbf_s=4.0, mttr_s=2.0),
              dropout=AgentDropout(0.1, 0.3))
    a, b = ChaosTrace(**kw), ChaosTrace(**kw)
    for name in ("link_up", "corrupt", "server_up", "agents_up"):
        np.testing.assert_array_equal(getattr(a, name), getattr(b, name))


def test_clamp_extension_and_recovery_queries():
    tr = _outage_trace(0, n=100)
    last = tr.fault_at((tr.n_steps - 1) * tr.dt_s)
    beyond = tr.fault_at(10 * tr.horizon_s)
    assert beyond.link_up == last.link_up       # clamp-extend
    # a trace that ends down answers "never in trace" == end_s
    down = ChaosTrace(dt_s=1.0, horizon_s=10.0, seed=0,
                      preemption=ServerPreemption(mtbf_s=1e-9, mttr_s=1e9))
    assert not down.fault_at(5.0).server_up
    assert down.next_server_up(5.0) == down.end_s


def test_is_clean_and_fraction_accounting():
    assert ChaosTrace(dt_s=0.5, horizon_s=10.0, seed=0).is_clean()
    assert chaos_clean().is_clean()
    storm = chaos_storm()
    assert not storm.is_clean()
    assert 0.0 < storm.outage_fraction() < 1.0
    assert storm.corruption_fraction() > 0.0


def test_process_parameter_validation():
    with pytest.raises(ValueError, match="p_fail"):
        LinkOutage(p_fail=1.5)
    with pytest.raises(ValueError, match="rate"):
        PacketCorruption(rate=-0.1)
    with pytest.raises(ValueError, match="mttr_s"):
        ServerPreemption(mttr_s=0.0)
    with pytest.raises(ValueError, match="dt_s"):
        ChaosTrace(dt_s=0.0)
    with pytest.raises(ValueError, match="n_agents"):
        ChaosTrace(n_agents=0)


# ---------------------------------------------------------------------------
# spec parsing (launch/serve.py --chaos-trace)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,match", [
    ([1, 2], "top level"),
    ({"no_such": 1}, "unknown top-level"),
    ({"dt_s": "fast"}, "must be a number"),
    ({"link_outage": 3}, "must be an object"),
    ({"link_outage": {"p_flail": 0.1}}, "unknown key"),
    ({"corruption": {"rate": 2.0}}, "rate"),
    ({"preemption": {"mtbf_s": -1.0}}, "mtbf_s"),
])
def test_chaos_from_spec_rejects_malformed(spec, match):
    with pytest.raises(ValueError, match=match):
        chaos_from_spec(spec)


def test_chaos_from_spec_round_trip_and_seed_override():
    spec = {"dt_s": 0.1, "horizon_s": 20.0, "seed": 9,
            "link_outage": {"p_fail": 0.2, "p_recover": 0.4},
            "corruption": {"rate": 0.05},
            "dropout": {"p_drop": 0.1, "p_rejoin": 0.5, "n_agents": 3}}
    tr = chaos_from_spec(spec)
    assert tr.seed == 9 and tr.dt_s == 0.1 and tr.n_agents == 3
    assert tr.link_outage.p_fail == 0.2
    assert chaos_from_spec(spec, seed=42).seed == 42
    # same spec -> same realized schedule (the CLI replay contract)
    np.testing.assert_array_equal(tr.link_up,
                                  chaos_from_spec(spec).link_up)


# ---------------------------------------------------------------------------
# payload checksum
# ---------------------------------------------------------------------------

def test_payload_checksum_detects_single_bit_flips():
    payload = np.arange(64, dtype=np.float32)
    c0 = payload_checksum(payload)
    assert c0 == payload_checksum(payload.copy())
    for bit in (0, 17, 64 * 32 - 1):
        assert payload_checksum(flip_bit(payload, bit)) != c0


@settings(deadline=None, max_examples=25)
@given(bit=st.integers(0, 32 * 32 - 1))
def test_payload_checksum_detects_any_bit(bit):
    payload = np.arange(32, dtype=np.int32)
    assert payload_checksum(flip_bit(payload, bit)) \
        != payload_checksum(payload)


# ---------------------------------------------------------------------------
# supervisor over the batched / fleet engines
# ---------------------------------------------------------------------------

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
QOS = QosClass("interactive", t0=1.3, e0=1.5)


@pytest.fixture(scope="module")
def built():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _traffic(cfg, n, seed=7, spacing=0.01):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size,
                          size=int(rng.integers(6, 17))).astype(np.int32),
             spacing * i) for i in range(n)]


def _run_batched(model, params, chaos, supervised, streams, **kw):
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=[QOS],
                                   max_batch=3)
    sup = ServingSupervisor(eng, chaos=chaos, supervised=supervised,
                            seed=3, **kw)
    rids = {}
    for i, (toks, t) in enumerate(streams):
        rids[sup.submit(toks, QOS.name, arrival_s=t)] = i
    out = {rids[r.request_id]: np.asarray(r.logits) for r in sup.drain()}
    return out, sup.report()


@pytest.fixture(scope="module")
def batched_ref(built):
    cfg, model, params = built
    streams = _traffic(cfg, 6)
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=[QOS],
                                   max_batch=3)
    for toks, t in streams:
        eng.submit(toks, QOS.name, arrival_s=t)
    return streams, [np.asarray(r.logits) for r in eng.drain()]


def test_supervisor_clean_trace_is_bitwise_passthrough(built, batched_ref):
    _, model, params = built
    streams, ref = batched_ref
    out, rep = _run_batched(model, params, chaos_clean(), True, streams)
    assert rep.clean and rep.delivered == len(streams)
    assert rep.retries == rep.failovers == rep.shed == 0
    for i, logits in enumerate(ref):
        np.testing.assert_array_equal(out[i], logits)


def test_supervisor_outage_fails_over_to_device_only(built, batched_ref):
    _, model, params = built
    streams, _ = batched_ref
    # sticky outage: retries exhaust, the supervisor re-solves the
    # codesign with the split pinned fully on-agent and keeps serving
    chaos = ChaosTrace(dt_s=0.005, horizon_s=2.0, seed=1,
                       link_outage=LinkOutage(p_fail=0.3, p_recover=0.05))
    assert chaos.outage_fraction() > 0.3
    out, rep = _run_batched(model, params, chaos, True, streams)
    assert rep.delivered == len(streams) and rep.failed == 0
    assert rep.failovers > 0
    _, rep_bare = _run_batched(model, params, chaos, False, streams)
    assert rep_bare.failed > 0
    assert rep.goodput > rep_bare.goodput


def test_supervisor_corruption_retransmits_bitwise(built, batched_ref):
    _, model, params = built
    streams, ref = batched_ref
    chaos = ChaosTrace(dt_s=0.005, horizon_s=2.0, seed=4,
                       corruption=PacketCorruption(rate=0.5))
    out, rep = _run_batched(model, params, chaos, True, streams)
    assert rep.retransmits > 0
    assert rep.delivered == len(streams)
    # a retransmitted payload is the same payload: bitwise identical
    for i, logits in enumerate(ref):
        np.testing.assert_array_equal(out[i], logits)


def test_supervisor_sheds_only_unmeetable_requests(built):
    cfg, model, params = built
    streams = _traffic(cfg, 3)
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=[QOS],
                                   max_batch=3)
    sup = ServingSupervisor(
        eng, chaos=ChaosTrace(dt_s=0.5, horizon_s=400.0, seed=0,
                              corruption=PacketCorruption(rate=0.001)),
        supervised=True, seed=3, deadline_factor=4.0)
    sup.submit(streams[0][0], QOS.name, arrival_s=0.0)
    sup.engine.fast_forward(50.0)            # long stall: deadline passed
    sup.submit(streams[1][0], QOS.name, arrival_s=0.0)   # unmeetable
    rid_ok = sup.submit(streams[2][0], QOS.name, arrival_s=49.0)
    outs = sup.drain()
    rep = sup.report()
    assert rep.shed >= 1
    assert any(r.request_id == rid_ok for r in outs)   # feasible: served
    assert rep.requests_total == rep.delivered + rep.shed + rep.failed


def test_fleet_dropout_triggers_reallocation(built):
    cfg, model, params = built
    qos = [QosClass("tight", t0=0.8, e0=8.0),
           QosClass("loose-a", t0=3.0, e0=4.0),
           QosClass("loose-b", t0=3.0, e0=4.0)]
    specs = [FleetAgentSpec(name=q.name, model=model, params=params,
                            sysp=SYSP, qos=q) for q in qos]
    chaos = ChaosTrace(dt_s=0.005, horizon_s=10.0, seed=9, n_agents=3,
                       dropout=AgentDropout(p_drop=0.3, p_rejoin=0.3))

    def run(supervised):
        fleet = FleetCoInferenceEngine(specs, allocator="joint",
                                       max_batch=2)
        sup = ServingSupervisor(fleet, chaos=chaos, supervised=supervised,
                                seed=3)
        rng = np.random.default_rng(0)
        for s in specs:
            for _ in range(3):
                sup.submit(s.name, rng.integers(
                    0, cfg.vocab_size, size=int(rng.integers(6, 17))))
        sup.drain()
        return sup.report()

    rep = run(True)
    assert rep.delivered == 9 and rep.failed == 0
    assert rep.reallocations > 0       # membership churn re-water-fills
    rep_bare = run(False)
    assert rep_bare.failed > 0         # bare fleet strands absent agents
