"""Per-module JAX cache hygiene for the tier-1 suite.

The suite compiles hundreds of XLA executables in one process (every
engine test re-jits its ladder of shape buckets).  Left to accumulate,
that state has segfaulted XLA's compiler late in long single-process
runs — deterministically in whichever test happens to compile next once
the process is saturated, while the same test passes in a fresh
process.  Dropping JAX's traced/compiled caches at module boundaries
keeps the process young; AOT executables already held by live objects
(CompiledForwardCache entries, module-scoped fixtures) stay valid, so
this costs only re-jits across module boundaries, never correctness.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
