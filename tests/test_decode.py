"""Continuous-batching decode over a quantized KV cache (DESIGN.md §12):
bitwise greedy-decode parity with the non-batched sequential reference
across plans and b_kv rungs, the decode compile-count bound, and the
engine's admission/retirement bookkeeping."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.core.quantization import QuantPlan
from repro.kernels.bucketing import seq_ladder
from repro.models.registry import build_model
from repro.runtime import (CompiledForwardCache, DecodeEngine, QosClass,
                           greedy_decode_reference)

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
QOS = QosClass("interactive", t0=3.5, e0=2.0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_split3():
    cfg = dataclasses.replace(get_smoke("qwen2-0.5b"), split_layer=3)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def shared_cache():
    """One compile cache for the whole module: decode executables are
    keyed on (cfg, bucket, b_kv, batch) — the quantized weight tree is a
    call argument — so every test reuses the same step functions."""
    return CompiledForwardCache()


def _ragged_traffic(cfg, n, seed, max_prompt=20, max_new=6):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, max_prompt + 1)))
        out.append((toks.astype(np.int32),
                    int(rng.integers(1, max_new + 1)), 0.05 * i))
    return out


def _assert_parity(model, params, target, b_kv, cache, *, n=6,
                   admission="continuous", max_batch=3):
    """Continuous-batched greedy decode == the non-batched sequential
    reference, token for token, for every request in a ragged stream."""
    eng = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                       max_batch=max_batch, max_new_tokens=6,
                       admission=admission, compile_cache=cache)
    eng.set_operating_point(QOS.name, target, b_kv)
    prompts = {}
    for toks, n_new, t in _ragged_traffic(model.cfg, n, seed=3):
        prompts[eng.submit(toks, QOS.name, max_new_tokens=n_new,
                           arrival_s=t)] = (toks, n_new)
    responses = eng.drain()
    assert len(responses) == n
    for r in responses:
        toks, n_new = prompts[r.request_id]
        assert len(r.tokens) == n_new
        assert r.b_kv == b_kv
        ref = greedy_decode_reference(model, eng.class_params(QOS.name),
                                      toks, n_new, b_kv=b_kv,
                                      compile_cache=cache)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)
    return eng


# ---------------------------------------------------------------------------
# bitwise parity: uniform-4 / uniform-8 x b_kv rungs, a mixed plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b_hat,b_kv", [(4, 4), (4, 8), (8, 4), (8, 8),
                                        (8, 16)])
def test_decode_parity_uniform(qwen, shared_cache, b_hat, b_kv):
    _, model, params = qwen
    _assert_parity(model, params, b_hat, b_kv, shared_cache)


@pytest.mark.parametrize("bits,b_kv", [((4, 8, 12), 8), ((4, 4, 6), 4)])
def test_decode_parity_mixed_plan(qwen_split3, bits, b_kv):
    """Per-layer mixed plans change only the weight tree handed to the
    shared step function — parity must survive them too."""
    _, model, params = qwen_split3
    plan = QuantPlan.from_layer_bits(list(bits))
    _assert_parity(model, params, plan, b_kv, CompiledForwardCache())


def test_decode_parity_barrier_policy(qwen, shared_cache):
    """The FIFO-barrier baseline runs the same step functions — it must
    be just as bitwise-exact (admission is scheduling, not numerics)."""
    _, model, params = qwen
    _assert_parity(model, params, 8, 8, shared_cache,
                   admission="barrier")


def test_decode_continuous_equals_barrier_tokens(qwen, shared_cache):
    """Same stream under both admission policies: identical tokens per
    request (the schedules differ; the numerics must not)."""
    _, model, params = qwen
    outs = {}
    for admission in ("continuous", "barrier"):
        eng = DecodeEngine(model, params, SYSP, classes=[QOS],
                           auto=False, max_batch=3, max_new_tokens=6,
                           admission=admission,
                           compile_cache=shared_cache)
        eng.set_operating_point(QOS.name, 8, 8)
        rids = {}
        for i, (toks, n_new, t) in enumerate(
                _ragged_traffic(model.cfg, 7, seed=11)):
            rids[eng.submit(toks, QOS.name, max_new_tokens=n_new,
                            arrival_s=t)] = i
        outs[admission] = {
            rids[r.request_id]: np.asarray(r.tokens)
            for r in eng.drain()}
    assert outs["continuous"].keys() == outs["barrier"].keys()
    for i in outs["continuous"]:
        np.testing.assert_array_equal(outs["continuous"][i],
                                      outs["barrier"][i])


def test_decode_streaming_matches_response(qwen, shared_cache):
    """on_token streams exactly the response's tokens, in order, at
    non-decreasing virtual emission times."""
    _, model, params = qwen
    eng = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                       max_batch=2, max_new_tokens=5,
                       compile_cache=shared_cache)
    eng.set_operating_point(QOS.name, 8, 8)
    seen = {}

    def on_token(rid, tok, t_s):
        seen.setdefault(rid, []).append((tok, t_s))

    for toks, n_new, t in _ragged_traffic(model.cfg, 4, seed=5,
                                          max_new=5):
        eng.submit(toks, QOS.name, max_new_tokens=n_new, arrival_s=t,
                   on_token=on_token)
    for r in eng.drain():
        toks = [t for t, _ in seen[r.request_id]]
        times = [s for _, s in seen[r.request_id]]
        np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                      np.asarray(r.tokens))
        assert times == sorted(times)
        assert times[-1] <= r.finished_s + 1e-9


def test_decode_eos_early_exit(qwen, shared_cache):
    """``eos_id`` retires a request at its first emission of that token:
    the response is the reference's prefix through the EOS, nothing is
    emitted past it, and batch-mates are untouched (DESIGN.md §13 —
    the fused chunk exits its while-loop early, which must be invisible
    to everything but the truncation point)."""
    _, model, params = qwen
    toks = np.arange(3, 15, dtype=np.int32)
    budget = 8
    eng0 = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                        max_batch=2, max_new_tokens=budget,
                        compile_cache=shared_cache)
    eng0.set_operating_point(QOS.name, 8, 8)
    ref = greedy_decode_reference(model, eng0.class_params(QOS.name),
                                  toks, budget, b_kv=8,
                                  compile_cache=shared_cache)
    # pick an EOS the stream emits strictly after the first token and
    # never before (so the prefill token does not trip it)
    cut = next(j for j in range(1, budget)
               if ref[j] not in ref[:j].tolist())
    eng = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                       max_batch=2, max_new_tokens=budget,
                       eos_id=int(ref[cut]), compile_cache=shared_cache)
    eng.set_operating_point(QOS.name, 8, 8)
    rid_eos = eng.submit(toks, QOS.name, arrival_s=0.0)
    rid_full = eng.submit(np.arange(5, 25, dtype=np.int32), QOS.name,
                          arrival_s=0.0)
    got = {r.request_id: r for r in eng.drain()}
    np.testing.assert_array_equal(np.asarray(got[rid_eos].tokens),
                                  ref[:cut + 1])
    # the batch-mate without an EOS in its stream runs to budget and
    # still matches its own reference
    mate_ref = greedy_decode_reference(
        model, eng.class_params(QOS.name),
        np.arange(5, 25, dtype=np.int32), len(got[rid_full].tokens),
        b_kv=8, compile_cache=shared_cache)
    np.testing.assert_array_equal(np.asarray(got[rid_full].tokens),
                                  mate_ref)


# ---------------------------------------------------------------------------
# compile-count bound + warmup (mirrors test_fastpath)
# ---------------------------------------------------------------------------

def test_decode_compile_count_bounded_and_warm_traffic_never_recompiles(
        qwen):
    cfg, model, params = qwen
    cache = CompiledForwardCache()
    classes = [QosClass("rt", t0=1.0, e0=1.0),
               QosClass("ia", t0=3.0, e0=2.0)]
    eng = DecodeEngine(model, params, SYSP, classes=classes, auto=False,
                       max_batch=4, max_new_tokens=8,
                       compile_cache=cache)
    eng.set_operating_point("rt", 4, 4)
    eng.set_operating_point("ia", 8, 8)
    max_prompt = 40
    warm = eng.warmup(max_prompt)
    n_kv = len({eng.b_kv_for(c.name) for c in classes})
    # prefill executables are keyed on (prompt bucket, cache bucket)
    # pairs — the in-executable slot scatter makes the cache shape part
    # of the graph — plus one fused-chunk executable per cache bucket
    t_rungs = seq_ladder(max_prompt + 8)
    pairs = sum(1 for s in seq_ladder(max_prompt) for t in t_rungs
                if t >= s)
    bound = (pairs + len(t_rungs)) * n_kv
    assert 0 < warm <= bound
    miss0 = cache.misses

    rng = np.random.default_rng(11)
    for i in range(14):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, max_prompt + 1)))
        eng.submit(toks, classes[i % 2].name,
                   max_new_tokens=int(rng.integers(1, 9)),
                   arrival_s=0.02 * i)
    responses = eng.drain()
    assert len(responses) == 14
    assert cache.misses == miss0       # warm traffic never recompiles
    assert len(cache) <= bound
    rep = eng.report()
    assert rep.compile_misses == cache.misses
    assert rep.compiled_variants == len(cache)
    assert rep.compile_hits > 0
    assert rep.requests_served == 14
    assert rep.tokens_generated == sum(len(r.tokens) for r in responses)


def test_decode_shared_compile_cache_across_engines(qwen):
    """Two decode engines sharing one cache: the second warmup compiles
    nothing new (the executables are plan-independent)."""
    _, model, params = qwen
    cache = CompiledForwardCache()
    a = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                     max_batch=4, max_new_tokens=8, compile_cache=cache)
    a.set_operating_point(QOS.name, 8, 8)
    n_a = a.warmup(32)
    assert n_a == len(cache) > 0
    b = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                     max_batch=4, max_new_tokens=8, compile_cache=cache)
    b.set_operating_point(QOS.name, 4, 8)   # same b_kv -> same graphs
    assert b.warmup(32) == 0


# ---------------------------------------------------------------------------
# construction + queue validation
# ---------------------------------------------------------------------------

def test_decode_engine_rejects_non_decoder_model():
    class _NoCache:
        pass

    with pytest.raises(TypeError):
        DecodeEngine(_NoCache(), {}, SYSP, classes=[QOS])


def test_decode_engine_rejects_bad_args(qwen):
    _, model, params = qwen
    with pytest.raises(ValueError):
        DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                     admission="fifo")
    with pytest.raises(ValueError):
        DecodeEngine(model, params, SYSP, classes=[], auto=False)
    eng = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False)
    with pytest.raises(KeyError):
        eng.submit(np.ones(4, np.int32), "no-such-class")
    with pytest.raises(ValueError):
        eng.submit(np.zeros(0, np.int32), QOS.name)
    with pytest.raises(ValueError):
        eng.submit(np.ones(4, np.int32), QOS.name, max_new_tokens=0)
