"""Fleet co-design + fleet serving engine (DESIGN.md §11): share
thresholds, water-filling vs equal split, shared caches, and bitwise
identity of the single-agent fleet."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import fleet as fl
from repro.core import codesign as cd
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CodesignCache,
                           CompiledForwardCache, FleetAgentSpec,
                           FleetCoInferenceEngine, QosClass)

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)


def _agent(name, t0, e0, lam=10.0, weight=1.0, sysp=SYSP):
    return fl.FleetAgent(name=name, lam=lam, sysp=sysp, t0=t0, e0=e0,
                         weight=weight, b_emb=8)

# one tight + two slack agents: the heterogeneous regime where the
# joint split beats 1/N (same scenario family as benchmarks/fleet.py)
TIGHT = _agent("tight", t0=0.8, e0=8.0)
LOOSE = [_agent("loose-a", t0=3.0, e0=4.0, lam=12.0),
         _agent("loose-b", t0=3.0, e0=4.0, lam=8.0)]


# ---------------------------------------------------------------------------
# core allocator
# ---------------------------------------------------------------------------

def test_shared_params_identity_at_full_share():
    assert fl.shared_params(SYSP, 1.0) == SYSP
    p = fl.shared_params(SYSP, 0.5)
    assert p.f_server_max == pytest.approx(SYSP.f_server_max * 0.5)
    assert p.f_max == SYSP.f_max  # the agent side is untouched


def test_shared_params_link_share():
    base = SystemParams(n_flop_agent=1e9, n_flop_server=1e9,
                        link_bps=2.0e6, emb_bytes_full=1e5)
    p = fl.shared_params(base, 0.25, share_link=True)
    assert p.link_bps == pytest.approx(5.0e5)
    assert fl.shared_params(base, 0.25).link_bps == base.link_bps


def test_min_share_monotone_in_bits():
    prev = 0.0
    for b in range(1, 17):
        s = fl.min_share_for(TIGHT, b)
        if s is None:
            break
        # a finer bit-width never needs less of the server
        assert s >= prev - 1e-9
        # the threshold share really is feasible for b
        p = fl.shared_params(TIGHT.sysp, s)
        assert cd.feasible_bitwidth(b, p, TIGHT.t0, TIGHT.e0,
                                    b_emb=TIGHT.b_emb)[0]
        prev = s
    assert b > 1  # at least some bit-widths are feasible


def test_joint_beats_equal_split_on_heterogeneous_fleet():
    agents = [TIGHT] + LOOSE
    joint = fl.solve_fleet(agents)
    equal = fl.solve_equal_split(agents)
    assert joint is not None and equal is not None
    assert abs(sum(joint.shares) - 1.0) < 1e-6
    assert joint.aggregate_bound < equal.aggregate_bound
    # the tight agent got share the slack agents never needed
    assert joint.shares[0] > equal.shares[0]
    assert joint.solutions[0].b_hat > equal.solutions[0].b_hat
    # slack agents keep their (maximal) bit-width on a smaller slice
    for j, e in zip(joint.solutions[1:], equal.solutions[1:]):
        assert j.b_hat == e.b_hat


def test_single_agent_fleet_matches_pair_solve():
    sol = fl.solve_fleet([TIGHT])
    assert sol is not None and sol.shares == (1.0,)
    direct = cd.solve_sca(TIGHT.lam, SYSP, TIGHT.t0, TIGHT.e0,
                          b_max=16, b_emb=TIGHT.b_emb)
    assert sol.solutions[0] == direct


def test_fleet_infeasible_returns_none():
    impossible = [_agent(f"a{i}", t0=0.16, e0=8.0) for i in range(8)]
    # each agent alone needs > 1/8 of the server just for the deadline
    assert fl.solve_fleet(impossible) is None
    assert fl.solve_equal_split(impossible) is None


def test_weight_steers_the_split():
    heavy = [_agent("tight-heavy", t0=0.8, e0=8.0, weight=100.0),
             _agent("tight-light", t0=0.85, e0=8.0, weight=1.0)]
    sol = fl.solve_fleet(heavy)
    assert sol is not None
    # the weighted agent's bound term dominates, so it is filled first
    # and ends at least as fine as its near-twin
    assert sol.solutions[0].b_hat >= sol.solutions[1].b_hat


def test_agent_validation():
    with pytest.raises(ValueError):
        fl.FleetAgent(name="x", lam=-1.0, sysp=SYSP, t0=1.0, e0=1.0)
    with pytest.raises(ValueError):
        fl.solve_fleet([TIGHT, TIGHT])  # duplicate names
    with pytest.raises(ValueError):
        fl.solve_fleet([])
    with pytest.raises(ValueError):
        fl.shared_params(SYSP, 0.0)


# ---------------------------------------------------------------------------
# fleet engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(1))


def _specs(model, params, n=3):
    qos = [QosClass("tight", t0=0.8, e0=8.0),
           QosClass("loose-a", t0=3.0, e0=4.0),
           QosClass("loose-b", t0=3.0, e0=4.0)]
    return [FleetAgentSpec(name=q.name, model=model, params=params,
                           sysp=SYSP, qos=q) for q in qos[:n]]


def _submit_stream(fleet, specs, n=4, seed=0):
    rng = np.random.default_rng(seed)
    for s in specs:
        for _ in range(n):
            fleet.submit(s.name, rng.integers(
                0, s.model.cfg.vocab_size, size=int(rng.integers(6, 17))))


def test_fleet_engine_single_agent_bitwise_identical(smoke_model):
    cfg, model, params = smoke_model
    qos = QosClass("solo", t0=1.3, e0=1.5)
    spec = FleetAgentSpec(name="solo", model=model, params=params,
                          sysp=SYSP, qos=qos)
    fleet = FleetCoInferenceEngine([spec], allocator="joint", max_batch=4)
    solo = BatchedCoInferenceEngine(model, params, SYSP, classes=[qos],
                                    max_batch=4)
    rng = np.random.default_rng(2)
    for _ in range(5):
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 17)))
        fleet.submit("solo", toks)
        solo.submit(toks, "solo")
    ra, rb = fleet.drain()["solo"], solo.drain()
    assert len(ra) == len(rb) == 5
    for x, y in zip(ra, rb):
        assert x.stats == y.stats
        np.testing.assert_array_equal(np.asarray(x.logits),
                                      np.asarray(y.logits))


def test_fleet_engine_serves_and_reports(smoke_model):
    cfg, model, params = smoke_model
    specs = _specs(model, params)
    fleet = FleetCoInferenceEngine(specs, allocator="joint", max_batch=2)
    _submit_stream(fleet, specs, n=3)
    out = fleet.drain()
    assert sorted(out) == sorted(s.name for s in specs)
    assert all(len(v) == 3 for v in out.values())
    rep = fleet.report()
    assert rep.requests_served == 9
    assert rep.n_agents == 3
    assert abs(sum(rep.shares) - 1.0) < 1e-6
    assert rep.makespan_s == max(p.clock_s for p in rep.per_agent)
    assert rep.aggregate_bound == pytest.approx(
        sum(p.bound for p in rep.per_agent))
    # joint split: the tight agent holds the largest share
    assert rep.per_agent[0].share == max(rep.shares)


def test_fleet_shared_codesign_cache_dedups_identical_agents(smoke_model):
    cfg, model, params = smoke_model
    qos_t = dict(t0=1.3, e0=1.5)
    specs = [FleetAgentSpec(name=f"twin-{i}", model=model, params=params,
                            sysp=SYSP, qos=QosClass(f"twin-{i}", **qos_t))
             for i in range(2)]
    cache = CodesignCache()
    FleetCoInferenceEngine(specs, allocator="equal", max_batch=2,
                           codesign_cache=cache)
    # identical decision inputs (lam, scaled sysp, budgets, b_emb):
    # the second member engine's solve must hit the first's entry
    assert cache.misses == 1
    assert cache.hits >= 1


def test_fleet_shared_compile_cache_across_same_config_agents(smoke_model):
    cfg, model, params = smoke_model
    specs = _specs(model, params, n=2)
    cc = CompiledForwardCache()
    fleet = FleetCoInferenceEngine(specs, allocator="equal", max_batch=2,
                                   compiled=True, compile_cache=cc)
    n_first = fleet.engines[specs[0].name].warmup(16)
    assert n_first >= 1
    # the twin agent's plans over the same ModelConfig reuse the
    # executables the first agent compiled wherever (plan, bucket) match
    b0 = fleet.engines[specs[0].name].solution_for(specs[0].qos.name).b_hat
    b1 = fleet.engines[specs[1].name].solution_for(specs[1].qos.name).b_hat
    n_second = fleet.engines[specs[1].name].warmup(16)
    if b0 == b1:
        assert n_second == 0
    else:
        assert n_second <= n_first
    _submit_stream(fleet, specs, n=2)
    fleet.drain()
    rep = fleet.report()
    assert rep.compiled_variants == len(cc)
    assert rep.compile_misses == n_first + n_second


def test_fleet_fifo_ranks_agents_by_oldest_arrival(smoke_model):
    """Cross-agent FIFO uses the oldest *arrival*, not the queue head:
    out-of-order submissions must not hide an agent's oldest request."""
    cfg, model, params = smoke_model
    specs = _specs(model, params, n=2)
    fleet = FleetCoInferenceEngine(specs, allocator="equal", max_batch=4)
    rng = np.random.default_rng(5)
    toks = lambda: rng.integers(0, cfg.vocab_size, size=8)  # noqa: E731
    # agent 0's head is late (5.0) but it holds the oldest request (1.0)
    fleet.submit(specs[0].name, toks(), arrival_s=5.0)
    fleet.submit(specs[0].name, toks(), arrival_s=1.0)
    fleet.submit(specs[1].name, toks(), arrival_s=2.0)
    assert fleet.engines[specs[0].name].oldest_pending_arrival() == 1.0
    name, responses = fleet.step()
    assert name == specs[0].name
    assert responses  # served that agent's batch first


def test_fleet_mixed_precision_plans_per_slice():
    """Mixed mode: the share split is decided on the uniform surrogate,
    then every member engine realizes a per-layer QuantPlan under its
    slice (DESIGN.md §11/§8)."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke("qwen2-0.5b"), split_layer=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    specs = [FleetAgentSpec(name="tight", model=model, params=params,
                            sysp=SYSP, qos=QosClass("tight", t0=0.8,
                                                    e0=8.0)),
             FleetAgentSpec(name="loose", model=model, params=params,
                            sysp=SYSP, qos=QosClass("loose", t0=3.0,
                                                    e0=4.0))]
    fleet = FleetCoInferenceEngine(specs, allocator="joint", max_batch=2,
                                   mixed_precision=True)
    rng = np.random.default_rng(0)
    for s in specs:
        for _ in range(2):
            fleet.submit(s.name, rng.integers(0, cfg.vocab_size, size=10))
    out = fleet.drain()
    assert all(len(v) == 2 for v in out.values())
    rep = fleet.report()
    tight, loose = rep.per_agent
    assert tight.share > loose.share
    assert len(tight.plan_bits) == len(loose.plan_bits) == 2
    # the bigger slice buys the tight agent at-least-as-fine layers
    assert min(loose.plan_bits) >= min(tight.plan_bits)
    assert fleet.solution_for("tight").bits == tight.plan_bits


def test_fleet_engine_validation(smoke_model):
    cfg, model, params = smoke_model
    specs = _specs(model, params, n=1)
    with pytest.raises(ValueError):
        FleetCoInferenceEngine([], allocator="joint")
    with pytest.raises(ValueError):
        FleetCoInferenceEngine(specs, allocator="best-effort")
    with pytest.raises(ValueError):
        FleetCoInferenceEngine(specs + specs)  # duplicate names
    tight = FleetAgentSpec(name="no", model=model, params=params,
                           sysp=SYSP, qos=QosClass("no", t0=1e-9, e0=1e-9))
    with pytest.raises(ValueError, match="infeasible"):
        FleetCoInferenceEngine([tight])
    fleet = FleetCoInferenceEngine(specs)
    with pytest.raises(KeyError):
        fleet.submit("ghost", np.arange(4))
