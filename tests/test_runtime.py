"""Training loop + co-inference engine integration tests (CPU, 1 device)."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.core.quantization import QuantConfig
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import AdamW
from repro.runtime import (CoInferenceEngine, QosClass, TrainConfig, Trainer)
from repro.runtime.qat import fake_quantize_agent


def _mk(arch="stablelm-3b", **tc):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    ds = MarkovLMDataset(MarkovLMConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, batch_size=8))
    loader = ShardedLoader(ds)
    tr = Trainer(model, AdamW(learning_rate=3e-3), mesh,
                 TrainConfig(log_every=5, **tc))
    return cfg, model, tr, loader, ds


def test_loss_decreases_on_markov_data():
    _, _, tr, loader, _ = _mk()
    _, hist = tr.fit(loader, 40)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1, hist


def test_qat_training_runs_and_learns():
    _, _, tr, loader, _ = _mk(qat_bits=8)
    _, hist = tr.fit(loader, 30)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_int8_ef_compression_training():
    _, _, tr, loader, _ = _mk(grad_compression="int8_ef")
    _, hist = tr.fit(loader, 30)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_resume_reproduces_stream():
    """Stop at step 20, restart from checkpoint -> identical metrics to an
    uninterrupted run (deterministic data + state round-trip)."""
    with tempfile.TemporaryDirectory() as d:
        cfg, model, tr, loader, ds = _mk()
        tr.ckpt = CheckpointManager(d, save_interval=10, keep=3)
        _, hist_a = tr.fit(loader, 20)

        # fresh trainer resumes from the step-20 checkpoint
        cfg2 = get_smoke("stablelm-3b")
        model2 = build_model(cfg2)
        tr2 = Trainer(model2, AdamW(learning_rate=3e-3),
                      make_host_mesh(), TrainConfig(log_every=5),
                      ckpt=CheckpointManager(d, save_interval=10))
        loader2 = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
            vocab_size=cfg2.vocab_size, seq_len=32, batch_size=8)))
        _, hist_b = tr2.fit(loader2, 10)
        assert tr2.step == 30
        assert hist_b[0]["step"] > 20  # resumed, not restarted

        # uninterrupted control run
        cfg3, model3, tr3, loader3, _ = _mk()
        _, hist_c = tr3.fit(loader3, 30)
        ctrl = {h["step"]: h["loss"] for h in hist_c}
        for h in hist_b:
            if h["step"] in ctrl:
                assert h["loss"] == pytest.approx(ctrl[h["step"]],
                                                  rel=1e-4), h


def test_qat_fake_quant_masks_agent_partition_only():
    cfg = get_smoke("stablelm-3b")   # split_layer=1 of 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    q = fake_quantize_agent(params, model.logical_axes(), cfg,
                            QuantConfig(bits=4))
    wq = params["layers"]["attn"]["wq"]
    wq_q = q["layers"]["attn"]["wq"]
    # layer 0 (agent) quantized, layers >= split untouched
    assert not bool(jnp.all(wq[0] == wq_q[0]))
    for i in range(cfg.split_layer, cfg.n_layers):
        assert bool(jnp.all(wq[i] == wq_q[i]))
    # embeddings untouched
    assert bool(jnp.all(params["embed"]["tok"] == q["embed"]["tok"]))


def test_checkpoint_zstd_soft_dependency():
    """Without zstandard, saves fall back to uncompressed (round-trip still
    works); compress=True demands the module with a clear error."""
    from repro.checkpoint import store
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    with tempfile.TemporaryDirectory() as d:
        step_compressed = store.zstd is not None
        path = store.save_tree(tree, d, 1)
        assert path.endswith("step_1")
        out, manifest = store.load_tree(d, 1, tree)
        assert manifest["compression"] == (
            "zstd" if step_compressed else "none")
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        # explicit uncompressed write works regardless of the module
        store.save_tree(tree, d, 2, compress=False)
        out2, m2 = store.load_tree(d, 2, tree)
        assert m2["compression"] == "none"
        np.testing.assert_array_equal(np.asarray(out2["a"]),
                                      np.asarray(tree["a"]))
        if store.zstd is None:
            with pytest.raises(ModuleNotFoundError, match="zstandard"):
                store.save_tree(tree, d, 3, compress=True)


# ---------------------------------------------------------------------------
# co-inference engine
# ---------------------------------------------------------------------------

def _engine(path="fake", arch="stablelm-3b"):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
    return cfg, model, params, CoInferenceEngine(model, params, sysp,
                                                 path=path)


def test_engine_full_precision_matches_monolithic():
    """b̂=16 (no quantization) through the split must equal model.forward."""
    cfg, model, params, eng = _engine()
    eng.configure(16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    logits, _ = eng.serve_batch({"tokens": toks})
    want, _ = model.forward(params, {"tokens": toks})
    # only the uplink quantization (b_emb=8) separates them
    assert float(jnp.mean(jnp.abs(logits - want))) < 0.05 * float(
        jnp.mean(jnp.abs(want)) + 1e-9)
    eng.b_emb = 16
    logits2, stats = eng.serve_batch({"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_engine_distortion_monotone_in_bits():
    """Lower b̂ -> larger output distortion (the paper's core trade-off)."""
    cfg, model, params, eng = _engine()
    eng.b_emb = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                              cfg.vocab_size)
    want, _ = model.forward(params, {"tokens": toks})
    dists = []
    for b in (2, 4, 8, 12):
        eng.configure(b)
        logits, _ = eng.serve_batch({"tokens": toks})
        dists.append(float(jnp.sum(jnp.abs(logits - want))))
    assert dists[0] > dists[-1]
    assert all(d >= 0 for d in dists)


def test_engine_kernel_path_close_to_fake_path():
    cfg, model, params, eng_f = _engine("fake")
    _, _, _, eng_k = _engine("kernel")
    for e in (eng_f, eng_k):
        e.b_emb = 16
        e.configure(8)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                              cfg.vocab_size)
    lf, _ = eng_f.serve_batch({"tokens": toks})
    lk, _ = eng_k.serve_batch({"tokens": toks})
    # different 8-bit quantizers (per-channel fake vs per-group kernel) —
    # outputs must agree to quantization precision
    assert float(jnp.mean(jnp.abs(lf - lk))) < 0.1 * float(
        jnp.mean(jnp.abs(lf)) + 1e-9)


def test_engine_auto_configure_respects_qos():
    _, _, _, eng = _engine()
    sol = eng.auto_configure(QosClass("rt", t0=1.3, e0=2.0))
    assert sol is not None
    assert sol.delay <= 1.3 * (1 + 1e-6)
    assert sol.energy <= 2.0 * (1 + 1e-6)
    assert eng.b_hat == sol.b_hat
    logits, stats = eng.serve_batch(
        {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert stats.b_hat == sol.b_hat


def test_engine_transport_bytes_scale_with_b_emb():
    _, _, _, eng = _engine()
    toks = jnp.zeros((3, 16), jnp.int32)
    eng.b_emb = 8
    _, s8 = eng.serve_batch({"tokens": toks})
    eng.b_emb = 4
    _, s4 = eng.serve_batch({"tokens": toks})
    # payload halves exactly; each row carries one 4-byte absmax scale, so
    # doubling the b_emb=4 bytes over-counts the scales by 4 per row
    assert s4.emb_bytes * 2 - s8.emb_bytes == 4 * toks.shape[0]
    assert len(s8.emb_row_bytes) == toks.shape[0]
    assert sum(s8.emb_row_bytes) == s8.emb_bytes


def test_engine_transport_bytes_use_real_containers():
    """Uplink accounting bills the containers that exist: nibble packing
    (pack_int4) for b_emb <= 4, int8 for 5..8 — not (n*bits+7)//8."""
    _, _, _, eng = _engine()
    toks = jnp.zeros((2, 16), jnp.int32)
    d = eng.cfg.d_model
    eng.b_emb = 2
    _, s2 = eng.serve_batch({"tokens": toks})
    assert s2.emb_row_bytes[0] == (16 * d + 1) // 2 + 4
    eng.b_emb = 6
    _, s6 = eng.serve_batch({"tokens": toks})
    assert s6.emb_row_bytes[0] == 16 * d + 4
