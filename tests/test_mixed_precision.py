"""Mixed-precision plans, per-layer statistics, the bit allocator, and
plan-aware serving (core/mixed_precision.py + DESIGN.md §8).

Covers the tentpole acceptance criteria:
  (a) a uniform QuantPlan reproduces the single-QuantConfig outputs
      bitwise (tree quantizers and the serving engine);
  (b) the allocator's plan achieves strictly lower Σ A^(l)·D^U than the
      best uniform b̂ at equal (T0, E0) feasibility, and measured output
      distortion orders the same way;
  (c) batched serving with two QoS classes on different plans is bitwise
      identical to sequential serving with the same plans.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import mixed_precision as mp
from repro.core.cost_model import SystemParams
from repro.core.distortion import measured_output_distortion
from repro.core.quantization import (QuantConfig, QuantPlan, as_plan,
                                     fake_quantize_tree, quantize_tree,
                                     quantize_tree_stacked)
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CodesignCache,
                           CoInferenceEngine, QosClass)
from repro.runtime.qat import fake_quantize_agent

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)


def _model(split=2, arch="qwen2-0.5b", seed=0):
    cfg = dataclasses.replace(get_smoke(arch), split_layer=split)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


# ---------------------------------------------------------------------------
# QuantPlan semantics
# ---------------------------------------------------------------------------

def test_plan_longest_prefix_resolution():
    plan = QuantPlan(entries=(("layers/1", 4), ("layers/1/attn", 3),
                              ("layers/0", 8)), default_bits=16)
    assert plan.resolve_bits("layers/1/attn/wq") == 3
    assert plan.resolve_bits("layers/1/ffn/wi") == 4
    assert plan.resolve_bits("layers/0/ffn/wi") == 8
    # '/'-boundary aware: layers/10 must not match the layers/1 prefix
    assert plan.resolve_bits("layers/10/attn/wq") == 16
    assert plan.resolve_bits("embed/tok") == 16
    assert plan.layer_bits(1) == 4        # exact prefix, not the attn leaf


def test_plan_uniform_and_aggregates():
    plan = QuantPlan.from_layer_bits([4, 8, 8])
    assert plan.layer_bit_list(3) == (4, 8, 8)
    assert plan.uniform_layer_bits(3) is None
    assert plan.uniform_layer_bits(2, prefix="layers") is None
    assert plan.mean_bits(3) == pytest.approx(20 / 3)
    uni = QuantPlan.from_layer_bits([6, 6])
    assert uni.uniform_layer_bits(2) == 6
    assert QuantPlan.uniform(5).uniform_layer_bits(7) == 5


def test_plan_key_and_hash_stability():
    a = QuantPlan.from_layer_bits([4, 8])
    b = QuantPlan.from_layer_bits([4, 8])
    c = QuantPlan.from_layer_bits([8, 4])
    assert a.key() == b.key() and a.plan_hash() == b.plan_hash()
    assert a.key() != c.key() and a.plan_hash() != c.plan_hash()
    assert hash(a.key()) == hash(b.key())  # usable as a dict key


def test_plan_validation():
    with pytest.raises(ValueError):
        QuantPlan(entries=(("layers/0", 0),))
    with pytest.raises(ValueError):
        QuantPlan.uniform(0)


# ---------------------------------------------------------------------------
# (a) uniform plan == single QuantConfig, bitwise
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {"layers": {"w": jax.random.normal(ks[0], (3, 16, 8)),
                       "b": jax.random.normal(ks[1], (3, 8))},
            "embed": jax.random.normal(ks[2], (32, 8))}


@pytest.mark.parametrize("bits", [3, 5, 8])
def test_uniform_plan_bitwise_equals_quantconfig(bits):
    tree = _tree()
    cfg = QuantConfig(bits=bits, granularity="per-channel")
    plan = as_plan(cfg)
    qc, qp = quantize_tree(tree, cfg), quantize_tree(tree, plan)
    assert bool(jnp.all(qc["embed"].codes == qp["embed"].codes))
    assert bool(jnp.all(qc["embed"].scale == qp["embed"].scale))
    fc, fp = fake_quantize_tree(tree, cfg), fake_quantize_tree(tree, plan)
    assert bool(jnp.all(fc["embed"] == fp["embed"]))
    assert bool(jnp.all(fc["layers"]["w"] == fp["layers"]["w"]))
    sc = quantize_tree_stacked(tree, cfg)["layers"]["w"]
    sp = quantize_tree_stacked(tree, plan)["layers"]["w"]
    assert bool(jnp.all(sc.codes == sp.codes))
    assert bool(jnp.all(sc.scale == sp.scale))
    assert sc.bits == sp.bits


def test_stacked_plan_per_layer_bits():
    tree = _tree(1)
    plan = QuantPlan.from_layer_bits([2, 8, 8])
    qt = quantize_tree_stacked(tree, plan)["layers"]["w"]
    # layer 0 has 2-bit codes (magnitude level 1), layers 1-2 full int8
    assert int(jnp.max(jnp.abs(qt.codes[0]))) <= 1
    assert int(jnp.max(jnp.abs(qt.codes[1]))) > 1
    assert qt.bits == 8   # records the max width for byte accounting
    # each layer's dequant matches quantizing that slice alone
    w1 = tree["layers"]["w"][1]
    alone = quantize_tree({"w": w1}, QuantConfig(bits=8))["w"]
    np.testing.assert_array_equal(np.asarray(qt.codes[1]),
                                  np.asarray(alone.codes))


def test_stacked_plan_wide_layers_reconstruct_better():
    """A plan mixing <=8 and >8-bit layers stacks into one int16
    container, and the wide layers really reconstruct *better* (the int8
    wraparound regression would make them worse)."""
    tree = _tree(2)
    w = tree["layers"]["w"]
    qt = quantize_tree_stacked(tree, QuantPlan.from_layer_bits(
        [4, 12, 16]))["layers"]["w"]
    assert qt.codes.dtype == jnp.int16 and qt.bits == 16
    errs = [float(jnp.max(jnp.abs(w[i] - qt.codes[i] * qt.scale[i])))
            for i in range(3)]
    assert errs[1] < errs[0] and errs[2] < errs[1]


def test_engine_uniform_plan_bitwise_identical():
    for path in ("fake", "kernel"):
        cfg, model, params = _model(split=2)
        eng = CoInferenceEngine(model, params, SYSP, path=path)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                                  cfg.vocab_size)
        eng.configure(8)
        a, _ = eng.serve_batch({"tokens": toks})
        eng.configure(QuantPlan.from_layer_bits([8, 8]))
        b, _ = eng.serve_batch({"tokens": toks})
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert eng.plan is None           # degenerated to the uniform path
        assert eng.b_eff == 8.0


# ---------------------------------------------------------------------------
# per-layer statistics
# ---------------------------------------------------------------------------

def test_decoder_layer_stats_shape_and_positivity():
    cfg, model, params = _model(split=3)
    stats = mp.decoder_layer_stats(params, 3)
    assert stats.n_layers == 3
    assert all(v > 0 for v in stats.lam)
    assert all(v >= 1.0 for v in stats.sens)   # normalized to min == 1
    assert min(stats.sens) == pytest.approx(1.0)
    # memoizable key: stable across recomputation
    again = mp.decoder_layer_stats(params, 3)
    assert stats.key() == again.key()


def test_layer_stats_validation():
    with pytest.raises(ValueError):
        mp.LayerStats(lam=(1.0,), sens=(1.0, 2.0))
    with pytest.raises(ValueError):
        mp.LayerStats(lam=(), sens=())


# ---------------------------------------------------------------------------
# the allocator
# ---------------------------------------------------------------------------

def test_max_mean_bits_monotone_and_uniform_floor():
    prev = 0.0
    for t0 in (1.1, 1.2, 1.4, 1.8):
        b = mp.max_mean_bits(SYSP, t0, 2.0)
        assert b is None or b >= prev
        prev = b or prev
    # the uniform floor agrees with the exhaustive oracle
    from repro.core.codesign import solve_oracle
    for t0, e0 in ((1.15, 0.95), (1.3, 1.5), (1.6, 2.5)):
        o = solve_oracle(30.0, SYSP, t0, e0)
        assert mp.best_uniform_bits(SYSP, t0, e0) == o.b_hat
    assert mp.max_mean_bits(SYSP, 1e-9, 1e-9) is None


def test_allocator_infeasible_and_degenerate():
    stats = mp.LayerStats(lam=(30.0,), sens=(1.0,))
    assert mp.allocate_bits(stats, SYSP, 1e-9, 1e-9) is None
    # single layer: the allocation *is* the best uniform bit-width
    sol = mp.allocate_bits(stats, SYSP, 1.3, 1.5)
    assert sol.bits == (sol.uniform_b,)
    assert sol.objective == pytest.approx(sol.uniform_objective)


def test_allocator_never_worse_and_strictly_better_somewhere():
    """Acceptance (b), model side: Σ A^(l)·D^U under the allocated plan
    is never above the best uniform b̂ at the same (T0, E0), and strictly
    below it on at least one budget."""
    cfg, model, params = _model(split=3)
    stats = mp.decoder_layer_stats(params, 3)
    strict = 0
    for t0, e0 in ((1.12, 0.92), (1.18, 1.05), (1.3, 1.5), (1.6, 2.5)):
        sol = mp.allocate_bits(stats, SYSP, t0, e0)
        assert sol is not None
        # equal feasibility: the plan's mean bits stay on the same
        # (T0, E0) frontier the uniform b̂ is the floor of
        b_star = mp.max_mean_bits(SYSP, t0, e0)
        assert sol.mean_bits <= b_star + 1e-9
        assert sol.delay <= t0 * (1 + 1e-6)
        assert sol.energy <= e0 * (1 + 1e-6)
        assert all(1 <= b <= 16 for b in sol.bits)
        assert sol.objective <= sol.uniform_objective * (1 + 1e-9)
        if sol.objective < sol.uniform_objective * (1 - 1e-6):
            strict += 1
    assert strict >= 1


def test_allocated_plan_lowers_measured_distortion():
    """Acceptance (b), measured side: the allocation's win on the bound
    shows up in ‖f(x,W) − f(x,Ŵ)‖₁ through the real forward."""
    cfg, model, params = _model(split=3)
    stats = mp.decoder_layer_stats(params, 3)
    sol = mp.allocate_bits(stats, SYSP, 1.12, 0.92)
    assert sol.objective < sol.uniform_objective  # mixed plan is distinct
    axes = model.logical_axes()
    x = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0,
                           cfg.vocab_size)

    def apply_fn(p, toks):
        return model.forward(p, {"tokens": toks})[0]

    d_uni = measured_output_distortion(
        apply_fn, params,
        fake_quantize_agent(params, axes, cfg,
                            QuantConfig(bits=sol.uniform_b), ste=False), x)
    d_mix = measured_output_distortion(
        apply_fn, params,
        fake_quantize_agent(params, axes, cfg, mp.plan_from_bits(sol.bits),
                            ste=False), x)
    assert float(d_mix) < float(d_uni)


# ---------------------------------------------------------------------------
# plan-aware serving
# ---------------------------------------------------------------------------

def test_engine_mixed_plan_kernel_containers():
    cfg, model, params = _model(split=2)
    eng = CoInferenceEngine(model, params, SYSP, path="kernel",
                            cache_weights=True)
    eng.configure(QuantPlan.from_layer_bits([4, 8]))
    assert eng.agent_path == "kernel-mixed[4/8]"
    assert eng.b_eff == pytest.approx(6.0)
    first = eng._qlinears
    # flipping away and back hits the plan-keyed weight cache
    eng.configure(16)
    eng.configure(QuantPlan.from_layer_bits([4, 8]))
    assert eng._qlinears is first
    # >8-bit layers fall back to full-precision matmuls on fake weights
    eng.configure(QuantPlan.from_layer_bits([3, 12]))
    assert eng.agent_path == "kernel-mixed[3/12]"
    logits, stats = eng.serve_batch(
        {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert stats.plan_bits == (3, 12)
    # no container cliff: a plan uniform at a non-legacy width keeps
    # kernel residency like its mixed neighbors, instead of degenerating
    # into the (4, 8)-only legacy branch's fake fallback
    eng.configure(QuantPlan.from_layer_bits([6, 6]))
    assert eng.agent_path == "kernel-mixed[6/6]"
    # legacy widths and the fake path still degenerate to the int path
    eng.configure(QuantPlan.from_layer_bits([8, 8]))
    assert eng.plan is None and eng.agent_path == "kernel-int8"
    feng = CoInferenceEngine(model, params, SYSP, path="fake")
    feng.configure(QuantPlan.from_layer_bits([6, 6]))
    assert feng.plan is None and feng.b_hat == 6
    # ...but never when degenerating would drop the plan's quantizer
    # metadata: a pot-log plan on a uniform-scheme engine stays a plan
    feng.configure(QuantPlan.from_layer_bits([6, 6], scheme="pot-log"))
    assert feng.plan is not None
    logits_plan, _ = feng.serve_batch({"tokens": jnp.zeros((1, 8),
                                                           jnp.int32)})
    peng = CoInferenceEngine(model, params, SYSP, path="fake",
                             scheme="pot-log")
    peng.configure(6)
    logits_ref, _ = peng.serve_batch({"tokens": jnp.zeros((1, 8),
                                                          jnp.int32)})
    np.testing.assert_array_equal(np.asarray(logits_plan),
                                  np.asarray(logits_ref))


def test_solve_mixed_cached_on_stats_not_names():
    cfg, model, params = _model(split=2)
    eng = CoInferenceEngine(model, params, SYSP)
    cache = CodesignCache()
    a = cache.solve_mixed(eng.layer_stats(), SYSP,
                          QosClass("a", t0=1.3, e0=1.5), b_max=16)
    b = cache.solve_mixed(eng.layer_stats(), SYSP,
                          QosClass("b", t0=1.3, e0=1.5), b_max=16)
    assert a == b
    assert cache.misses == 1 and cache.hits == 1
    # disjoint keyspace from the uniform solver
    cache.solve(eng.lam, SYSP, QosClass("a", t0=1.3, e0=1.5), b_max=16)
    assert cache.misses == 2


@pytest.mark.parametrize("path", ["fake", "kernel"])
def test_batched_mixed_two_classes_bitwise_vs_sequential(path):
    """Acceptance (c): two QoS classes on *different* plans through the
    batched engine produce per-request logits identical to sequential
    serving with the same plans."""
    cfg, model, params = _model(split=2, seed=1)
    classes = [QosClass("tight", t0=1.15, e0=0.95),
               QosClass("loose", t0=1.3, e0=1.5)]
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=classes,
                                   max_batch=3, path=path,
                                   mixed_precision=True)
    pa, pb = eng.plan_for("tight"), eng.plan_for("loose")
    assert pa.key() != pb.key()   # genuinely different plans
    rng = np.random.default_rng(5)
    sent = {}
    for i in range(8):
        qos = classes[i % 2].name
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 15)))
        sent[eng.submit(toks, qos)] = (toks, qos)
    responses = eng.drain()
    assert len(responses) == len(sent)

    seq = CoInferenceEngine(model, params, SYSP, path=path,
                            cache_weights=True)
    for r in responses:
        toks, qos = sent[r.request_id]
        sol = eng.solution_for(qos)
        seq.configure(eng.plan_for(qos), sol.f, sol.f_server)
        want, _ = seq.serve_batch(
            {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      np.asarray(want[0]))
    # batches of a mixed class report their per-layer bits
    for b in eng.batch_history:
        sol = eng.solution_for(b.qos)
        if len(set(sol.bits)) > 1:
            assert b.plan_bits == sol.bits
