"""Per-arch smoke tests + model-level equivalences.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs (assignment
§ARCHITECTURES), plus decode-vs-prefill and MoE/SSM equivalence oracles.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke, smoke_shape
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.registry import build_model


def _batch_for(model, cfg, shape, seed=0):
    specs = model.input_specs(shape)
    key = jax.random.PRNGKey(seed)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            out[k] = jax.random.randint(key, s.shape, 0,
                                        max(cfg.vocab_size, 2))
        else:
            out[k] = jax.random.normal(key, s.shape, s.dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    shape = smoke_shape("train")
    batch = _batch_for(model, cfg, shape)
    params = model.init(jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    gsum = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.abs(g)), grads, jnp.float32(0.0))
    assert jnp.isfinite(gsum) and float(gsum) > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_shapes(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    shape = smoke_shape("train")
    batch = _batch_for(model, cfg, shape)
    logits, _ = model.forward(params := model.init(jax.random.PRNGKey(2)),
                              batch)
    assert logits.shape[0] == shape.global_batch
    assert logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.any(jnp.isnan(logits))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    shape = smoke_shape("prefill")
    batch = _batch_for(model, cfg, shape)
    params = model.init(jax.random.PRNGKey(3))
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (shape.global_batch, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    # decode into a fresh, larger cache (prefill caches are snug)
    logits2, cache2 = model.decode_step(
        params, model.init_cache(shape.global_batch, shape.seq_len + 8),
        {"token": tok, "pos": jnp.zeros((shape.global_batch,), jnp.int32)})
    assert logits2.shape == (shape.global_batch, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits2)))
    assert int(cache2["len"][0]) == 1


def test_decode_matches_prefill_dense():
    """Greedy decode after prefill == teacher-forced forward (dense LM)."""
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0,
                              cfg.vocab_size)
    # full forward logits at position t
    full_logits, _ = model.forward(params, {"tokens": toks})
    # prefill on the first 8, then decode token-by-token with the cache
    cache = model.init_cache(2, 16)
    logits, cache_pre = model.prefill(params, {"tokens": toks[:, :8]})
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, 7]),
                               rtol=2e-3, atol=2e-3)
    # continue: feed ground-truth tokens 8..11
    cache = model.init_cache(2, 16)
    for t in range(8):
        dl, cache = model.decode_step(
            params, cache, {"token": toks[:, t:t + 1],
                            "pos": jnp.full((2,), t, jnp.int32)})
        np.testing.assert_allclose(np.asarray(dl),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_chunked_ce_equals_direct():
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 32), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(8), (2, 32), 0,
                                cfg.vocab_size)
    x, _ = model._embed(params, {"tokens": toks})
    h, _ = model._run_stack(params["layers"], x,
                            jnp.broadcast_to(jnp.arange(32), (2, 32)))
    h = L.apply_norm(cfg, h, params["final_norm"])
    direct = L.softmax_cross_entropy(
        L.unembed(cfg, params["embed"], h), labels)
    chunked = L.chunked_cross_entropy(cfg, h, params["embed"], labels,
                                      chunk=8)
    assert float(direct) == pytest.approx(float(chunked), rel=1e-5)


def test_moe_dispatch_matches_dense_oracle():
    cfg = dataclasses.replace(get_smoke("qwen3-moe-235b-a22b"),
                              capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(9))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 32, cfg.d_model))
    y_dense, _ = M.apply_moe_dense(cfg, lp, x)
    y_disp, _ = M.apply_moe_dispatch(cfg, lp, x, group_size=32)
    np.testing.assert_allclose(np.asarray(y_disp), np.asarray(y_dense),
                               rtol=5e-4, atol=5e-4)


def test_moe_chunked_dispatch_equals_single_shot():
    cfg = dataclasses.replace(get_smoke("qwen3-moe-235b-a22b"),
                              capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(11))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 64, cfg.d_model))
    y1, _ = M._dispatch_one(cfg, lp, x, group_size=32)
    y2, _ = M.apply_moe_dispatch(cfg, lp, x, group_size=32,
                                 max_chunk_tokens=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_mamba_decode_matches_forward():
    """Step-by-step Mamba recurrence == chunked parallel forward."""
    cfg = get_smoke("jamba-1.5-large-398b")
    key = jax.random.PRNGKey(13)
    p, _ = S.init_mamba(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(14), (2, 16, cfg.d_model))
    y_par = S.mamba_forward(cfg, p, x, chunk=4)
    state = S.mamba_init_state(cfg, 2)
    ys = []
    for t in range(16):
        y, state = S.mamba_decode_step(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_decode_matches_forward():
    cfg = get_smoke("xlstm-350m")
    p, _ = S.init_mlstm(cfg, jax.random.PRNGKey(15))
    x = jax.random.normal(jax.random.PRNGKey(16), (2, 12, cfg.d_model))
    y_par = S.mlstm_forward(cfg, p, x, chunk=4)
    state = S.mlstm_init_state(cfg, 2)
    ys = []
    for t in range(12):
        y, state = S.mlstm_decode_step(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_par), rtol=2e-3, atol=2e-3)


def test_slstm_decode_matches_forward():
    cfg = get_smoke("xlstm-350m")
    p, _ = S.init_slstm(cfg, jax.random.PRNGKey(17))
    x = jax.random.normal(jax.random.PRNGKey(18), (2, 10, cfg.d_model))
    y_par = S.slstm_forward(cfg, p, x)
    state = S.slstm_init_state(cfg, 2)
    ys = []
    for t in range(10):
        y, state = S.slstm_decode_step(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_par), rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_naive():
    B, S_, H, dh = 2, 24, 4, 8
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(19), 3)
    q = jax.random.normal(kq, (B, S_, H, dh))
    k = jax.random.normal(kk, (B, S_, H, dh))
    v = jax.random.normal(kv, (B, S_, H, dh))
    out = L.blockwise_attention(q, k, v, causal=True, q_block=8, kv_block=8)
    # naive reference
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * dh ** -0.5
    mask = jnp.tril(jnp.ones((S_, S_), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_blockwise_attention_gqa_and_window():
    B, S_, KV, G, dh = 1, 32, 2, 3, 8
    H = KV * G
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(20), 3)
    q = jax.random.normal(kq, (B, S_, H, dh))
    k = jax.random.normal(kk, (B, S_, KV, dh))
    v = jax.random.normal(kv, (B, S_, KV, dh))
    out = L.blockwise_attention(q, k, v, causal=True, window=8,
                                q_block=16, kv_block=16)
    # reference with expanded KV
    ke = jnp.repeat(k, G, axis=2)
    ve = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, ke) * dh ** -0.5
    idx = jnp.arange(S_)
    mask = (idx[:, None] >= idx[None, :]) & \
        ((idx[:, None] - idx[None, :]) < 8)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), ve)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_run_layers_split_composes():
    """Co-inference invariant: agent[0,k) then server[k,L) == full stack."""
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(21))
    toks = jax.random.randint(jax.random.PRNGKey(22), (2, 16), 0,
                              cfg.vocab_size)
    x, pos = model._embed(params, {"tokens": toks})
    full, _ = model._run_stack(params["layers"], x, pos)
    for k in (1, 2, 3):
        a, _ = model.run_layers(params, x, pos, 0, k)
        b, _ = model.run_layers(params, a, pos, k, cfg.n_layers)
        np.testing.assert_allclose(np.asarray(b), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)
