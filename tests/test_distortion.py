"""Output-distortion approximation (paper §III, Prop 3.1, Fig. 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.core.distortion import (chain_bound_coefficients, fc_chain_bound,
                                   estimate_grad_norm_H, induced_l1_norm,
                                   measured_output_distortion,
                                   param_distortion, taylor_surrogate_bound)
from repro.core.quantization import QuantConfig, quantize_dequantize
from repro.models.fcdnn import apply_fcdnn, init_fcdnn, layer_dims


def _quantize_weights(ws, bits, scheme="uniform"):
    cfg = QuantConfig(bits=bits, scheme=scheme, granularity="per-tensor")
    return [quantize_dequantize(w, cfg) for w in ws]


def test_induced_l1_norm_definition():
    w = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])
    # max column abs-sum: col0 = 4, col1 = 2.5
    assert float(induced_l1_norm(w)) == pytest.approx(4.0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_prop_induced_norm_submultiplicative(seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (8, 6))
    b = jax.random.normal(k2, (6, 5))
    assert float(induced_l1_norm(a @ b)) <= \
        float(induced_l1_norm(a)) * float(induced_l1_norm(b)) * (1 + 1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_prop_operator_bound_holds(seed):
    """||Wx||_1 <= ||W||_1 ||x||_1 — the proof's key step."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (8, 6))
    x = jax.random.normal(k2, (6,))
    assert float(jnp.sum(jnp.abs(w @ x))) <= \
        float(induced_l1_norm(w)) * float(jnp.sum(jnp.abs(x))) * (1 + 1e-5)


@pytest.mark.parametrize("bits", [3, 4, 6, 8])
@pytest.mark.parametrize("scheme", ["uniform", "pot-log"])
def test_prop31_chain_bound_upper_bounds_output(bits, scheme):
    """Proposition 3.1 on the paper's FCDNN-16 (reduced widths for CI)."""
    dims = [32, 24, 16, 24, 16, 32]   # same family, CI-sized
    ws = init_fcdnn(jax.random.PRNGKey(0), dims)
    ws_hat = _quantize_weights(ws, bits, scheme)
    # Assumption 1: ||x||_1 <= 1
    x = jax.random.normal(jax.random.PRNGKey(1), (16, dims[0]))
    x = x / jnp.sum(jnp.abs(x), axis=-1, keepdims=True)
    out = apply_fcdnn(ws, x)
    out_hat = apply_fcdnn(ws_hat, x)
    measured = float(jnp.max(jnp.sum(jnp.abs(out - out_hat), axis=-1)))
    bound = float(fc_chain_bound(ws, ws_hat))
    assert measured <= bound * (1 + 1e-5), (measured, bound)


def test_prop31_bound_tightens_with_bits():
    dims = [32, 24, 16, 24, 32]
    ws = init_fcdnn(jax.random.PRNGKey(2), dims)
    prev = np.inf
    for bits in (3, 5, 7, 9):
        ws_hat = _quantize_weights(ws, bits)
        b = float(fc_chain_bound(ws, ws_hat))
        assert b <= prev * (1 + 1e-6)
        prev = b


def test_chain_coefficients_independent_of_quantized_weights():
    """Remark 3.1: A^(l) depends only on W and tau, not on W_hat."""
    ws = init_fcdnn(jax.random.PRNGKey(3), [16, 12, 8, 16])
    taus = [jnp.float32(0.1)] * len(ws)
    c1 = chain_bound_coefficients(ws, taus)
    c2 = chain_bound_coefficients(ws, taus)
    for a, b in zip(c1, c2):
        assert float(a) == float(b)
    assert all(float(c) > 0 for c in c1)


def test_param_distortion_is_l1():
    a = {"w": jnp.asarray([1.0, -1.0]), "v": jnp.asarray([[2.0]])}
    b = {"w": jnp.asarray([0.0, 1.0]), "v": jnp.asarray([[0.0]])}
    assert float(param_distortion(a, b)) == pytest.approx(5.0)


def test_taylor_surrogate_tracks_measured(capsys):
    """Eq. (17): H ||W - W_hat||_1 upper-bounds measured distortion for
    small perturbations (first-order regime)."""
    dims = [24, 16, 12, 24]
    ws = init_fcdnn(jax.random.PRNGKey(4), dims)
    xs = jax.random.normal(jax.random.PRNGKey(5), (8, dims[0]))
    xs = xs / jnp.sum(jnp.abs(xs), axis=-1, keepdims=True)

    def apply_list(params, x):
        return apply_fcdnn(params, x)

    H = estimate_grad_norm_H(apply_list, ws, xs)
    ws_hat = _quantize_weights(ws, 10)   # fine quantization: linear regime
    measured = float(measured_output_distortion(apply_list, ws, ws_hat, xs))
    bound = float(taylor_surrogate_bound(H, ws, ws_hat))
    assert measured <= bound * (1 + 1e-4), (measured, bound)
