"""Quantizer unit + property tests (core/quantization.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.core.quantization import (QuantConfig, dequantize, max_quant_error,
                                     pack_int4, qat_quantize, quantize,
                                     quantize_dequantize, quantize_tree,
                                     quantize_tree_stacked, unpack_int4,
                                     fake_quantize_tree, wire_bytes,
                                     _absmax)

SCHEMES = ("uniform", "pot-log")


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# basic invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("gran", ["per-tensor", "per-channel", "per-group"])
def test_qdq_error_bounded(scheme, bits, gran):
    x = _rand(0, (256, 64))
    cfg = QuantConfig(bits=bits, scheme=scheme, granularity=gran)
    xq = quantize_dequantize(x, cfg)
    err = jnp.max(jnp.abs(x - xq))
    tau = max_quant_error(x, cfg)
    assert float(err) <= float(tau) * (1 + 1e-5), (float(err), float(tau))


@pytest.mark.parametrize("scheme", SCHEMES)
def test_qdq_sign_preserved(scheme):
    x = _rand(1, (128, 32))
    cfg = QuantConfig(bits=4, scheme=scheme)
    xq = quantize_dequantize(x, cfg)
    # paper §II-C: sign bits are kept; only magnitudes quantized
    assert bool(jnp.all((jnp.sign(xq) == jnp.sign(x)) | (xq == 0)))


@pytest.mark.parametrize("scheme", SCHEMES)
def test_qdq_idempotent(scheme):
    x = _rand(2, (64, 64))
    cfg = QuantConfig(bits=5, scheme=scheme, granularity="per-tensor")
    x1 = quantize_dequantize(x, cfg)
    x2 = quantize_dequantize(x1, cfg)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=1e-5, atol=1e-6)


def test_distortion_monotone_in_bits():
    """Paper Remark 4.1: more bits -> less distortion."""
    x = _rand(3, (512, 128))
    prev = np.inf
    for bits in range(2, 10):
        cfg = QuantConfig(bits=bits, scheme="uniform",
                          granularity="per-channel")
        d = float(jnp.mean(jnp.abs(x - quantize_dequantize(x, cfg))))
        assert d <= prev * (1 + 1e-6), (bits, d, prev)
        prev = d


def test_int_code_roundtrip():
    x = _rand(4, (256, 64))
    cfg = QuantConfig(bits=8, scheme="uniform", granularity="per-channel")
    qt = quantize(x, cfg)
    assert qt.codes.dtype == jnp.int8
    xq = dequantize(qt)
    np.testing.assert_allclose(np.asarray(xq),
                               np.asarray(quantize_dequantize(x, cfg)),
                               rtol=1e-5, atol=1e-6)


def test_quantized_tensor_astype_transparent():
    """astype() on QuantizedTensor dequantizes (dequant-on-read serving)."""
    x = _rand(5, (64, 32))
    cfg = QuantConfig(bits=8, scheme="uniform")
    qt = quantize(x, cfg)
    y = qt.astype(jnp.float32)
    assert y.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(y - x))) < float(max_quant_error(x, cfg)) \
        * 1.01


def test_pack_unpack_int4():
    codes = jnp.asarray(
        np.random.default_rng(0).integers(-7, 8, (64, 32)), jnp.int8)
    packed = pack_int4(codes.T).T  # pack along first axis via transpose
    codes2 = unpack_int4(packed.T).T
    assert bool(jnp.all(codes == codes2))


def test_tree_quantization_skips_small_leaves():
    tree = {"w": _rand(6, (32, 16)), "b": _rand(7, (16,)),
            "n": jnp.ones((8,))}
    cfg = QuantConfig(bits=4)
    fq = fake_quantize_tree(tree, cfg)
    assert bool(jnp.all(fq["b"] == tree["b"]))  # 1-D untouched
    assert not bool(jnp.all(fq["w"] == tree["w"]))
    qt = quantize_tree(tree, cfg)
    assert qt["w"].codes.dtype == jnp.int8
    assert qt["b"] is tree["b"]


def test_stacked_tree_per_layer_scales():
    w = jnp.stack([_rand(8, (16, 8)), _rand(9, (16, 8)) * 100.0])
    cfg = QuantConfig(bits=8, granularity="per-channel")
    qt = quantize_tree_stacked({"w": w}, cfg)["w"]
    # layer 1 is 100x larger -> its scales must be ~100x larger
    s0, s1 = np.asarray(qt.scale[0]), np.asarray(qt.scale[1])
    assert np.median(s1 / np.maximum(s0, 1e-12)) > 10


def test_qat_straight_through_gradient():
    x = _rand(10, (32, 16))
    cfg = QuantConfig(bits=4)

    def f(x):
        return jnp.sum(qat_quantize(x, cfg) ** 2)

    g = jax.grad(f)(x)
    # STE: d/dx sum(q(x)^2) = 2 q(x) (identity through the quantizer)
    expect = 2 * quantize_dequantize(x, cfg)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# per-group -> per-channel fallback (contraction axis not divisible)
# ---------------------------------------------------------------------------

def test_absmax_per_group_fallback_equals_per_channel():
    """group_size that does not tile the contraction axis falls back to
    per-channel scales — bitwise the same reduction."""
    x = _rand(11, (100, 16))             # 100 % 128 != 0
    grp = QuantConfig(bits=8, granularity="per-group", group_size=128)
    chan = QuantConfig(bits=8, granularity="per-channel")
    np.testing.assert_array_equal(np.asarray(_absmax(x, grp)),
                                  np.asarray(_absmax(x, chan)))
    # sanity: a divisible axis does NOT fall back (per-row groups differ)
    x2 = _rand(12, (256, 16))
    assert _absmax(x2, grp).shape == (256, 16)
    assert _absmax(x2, chan).shape == (1, 16)


@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_roundtrip_under_group_fallback(bits):
    """quantize/dequantize under the fallback matches both the fake-quant
    reference and the explicit per-channel config."""
    x = _rand(13, (100, 16))
    grp = QuantConfig(bits=bits, granularity="per-group", group_size=128)
    chan = QuantConfig(bits=bits, granularity="per-channel")
    qt = quantize(x, grp)
    np.testing.assert_allclose(np.asarray(dequantize(qt)),
                               np.asarray(quantize_dequantize(x, grp)),
                               rtol=1e-5, atol=1e-6)
    qt_chan = quantize(x, chan)
    np.testing.assert_array_equal(np.asarray(qt.codes),
                                  np.asarray(qt_chan.codes))
    np.testing.assert_array_equal(np.asarray(qt.scale),
                                  np.asarray(qt_chan.scale))
    # the fallback still bounds the error by the per-channel tau
    err = float(jnp.max(jnp.abs(x - dequantize(qt))))
    assert err <= float(max_quant_error(x, chan)) * (1 + 1e-5)


# ---------------------------------------------------------------------------
# realizable wire sizes
# ---------------------------------------------------------------------------

def test_wire_bytes_uses_real_containers():
    # <= 4 bits: two codes per byte (pack_int4), NOT (n*bits+7)//8
    assert wire_bytes(100, 3) == 50
    assert wire_bytes(101, 4) == 51
    # 5..8 bits: int8-resident, one byte per code
    assert wire_bytes(100, 6) == 100
    assert wire_bytes(100, 8) == 100
    # 9..16: int16
    assert wire_bytes(100, 12) == 200


@pytest.mark.parametrize("bits", [9, 12, 16])
def test_wide_codes_use_int16_container(bits):
    """9..16-bit codes need int16: an int8 cast would silently wrap and
    make *higher*-precision layers reconstruct worse than 8-bit ones."""
    x = _rand(15, (128, 32))
    cfg = QuantConfig(bits=bits, scheme="uniform", granularity="per-channel")
    qt = quantize(x, cfg)
    assert qt.codes.dtype == jnp.int16
    err = float(jnp.max(jnp.abs(x - dequantize(qt))))
    assert err <= float(max_quant_error(x, cfg)) * (1 + 1e-5)
    # monotonicity across the container boundary survives
    err8 = float(jnp.max(jnp.abs(
        x - dequantize(quantize(x, QuantConfig(bits=8))))))
    assert err <= err8 * (1 + 1e-6)
    with pytest.raises(ValueError):
        quantize(x, QuantConfig(bits=17))


def test_nbytes_effective_matches_pack_int4_wire_size():
    x = _rand(14, (64, 32))
    for bits, code_bytes in ((3, 64 * 32 // 2), (4, 64 * 32 // 2),
                             (6, 64 * 32), (8, 64 * 32)):
        qt = quantize(x, QuantConfig(bits=bits, granularity="per-channel"))
        scale_bytes = int(np.prod(qt.scale.shape)) * 4
        assert qt.nbytes_effective() == code_bytes + scale_bytes, bits
    # bits <= 4 really fits the packed container pack_int4 produces
    qt4 = quantize(x, QuantConfig(bits=4, granularity="per-channel"))
    packed = pack_int4(qt4.codes.T).T
    assert int(np.prod(packed.shape)) == wire_bytes(64 * 32, 4)


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 10),
       scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2 ** 16))
def test_prop_uniform_error_le_half_step(bits, scale, seed):
    x = _rand(seed, (64, 16), scale)
    cfg = QuantConfig(bits=bits, scheme="uniform", granularity="per-tensor")
    xq = quantize_dequantize(x, cfg)
    levels = 2 ** (bits - 1) - 1
    step = float(jnp.max(jnp.abs(x))) / levels
    assert float(jnp.max(jnp.abs(x - xq))) <= step / 2 * (1 + 1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bits=st.integers(1, 8))
def test_prop_qdq_never_amplifies(seed, bits):
    x = _rand(seed, (32, 32))
    cfg = QuantConfig(bits=bits, scheme="uniform", granularity="per-tensor")
    xq = quantize_dequantize(x, cfg)
    assert float(jnp.max(jnp.abs(xq))) <= float(jnp.max(jnp.abs(x))) \
        * (1 + 1e-5)
