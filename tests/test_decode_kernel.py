"""The fused dequant-attend decode kernel vs its shared reference.

``quantized_decode_attention`` (kernels/decode_attn.py) is the decode
engine's attention primitive: it reads int8-held KV codes + per-vector
scales straight from the cache and dequantizes per-tile in VMEM.  The
house bitwise-parity invariant extends down to it:
``quantized_decode_attention_ref`` — the plain-Python oracle built on
the SAME per-tile update — must match the kernel bit for bit, across
stored bit-widths, head shapes, cache buckets, tile widths, and sliding
windows; and cache-bucket padding must be invisible to the outputs.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import (quantized_decode_attention,
                                       quantized_decode_attention_ref)
from repro.kernels.quantize import kv_quantize


def _case(b, h, kv, dh, t, b_kv, seed=0):
    """Random [B, 1, H, dh] query + quantized [B, T, KV, dh] cache with
    ragged per-row lengths (every row shorter than the bucket)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    if b_kv < 16:
        kc, ks = kv_quantize(k, b_kv)
        vc, vs = kv_quantize(v, b_kv)
    else:
        kc, vc = k, v
        ks = jnp.ones(k.shape[:-1], jnp.float32)
        vs = jnp.ones(v.shape[:-1], jnp.float32)
    lens = jnp.asarray(rng.integers(1, t + 1, size=b), jnp.int32)
    return q, kc, vc, ks, vs, lens


# the ladder the engine actually serves: every stored bit-width times a
# head-dim / cache-bucket grid covering single- and multi-tile grids
LADDER = [(dh, t, bt)
          for dh in (8, 16, 32)
          for (t, bt) in ((16, 16), (64, 16), (128, 32))]


@pytest.mark.parametrize("b_kv", [4, 8, 16])
@pytest.mark.parametrize("dh,t,bt", LADDER)
def test_kernel_matches_reference_bitwise(b_kv, dh, t, bt):
    q, kc, vc, ks, vs, lens = _case(2, 4, 2, dh, t, b_kv,
                                    seed=dh * 1000 + t + b_kv)
    out = quantized_decode_attention(q, kc, vc, ks, vs, lens, block_t=bt)
    want = quantized_decode_attention_ref(q, kc, vc, ks, vs, lens,
                                          block_t=bt)
    assert np.array_equal(np.asarray(out), np.asarray(want)), (
        f"b_kv={b_kv} dh={dh} t={t} bt={bt}: kernel diverged from the "
        "shared reference")


@pytest.mark.parametrize("b_kv", [4, 8])
@pytest.mark.parametrize("window", [3, 7])
def test_kernel_matches_reference_sliding_window(b_kv, window):
    q, kc, vc, ks, vs, lens = _case(2, 4, 2, 16, 64, b_kv, seed=window)
    out = quantized_decode_attention(q, kc, vc, ks, vs, lens,
                                     window=window, block_t=16)
    want = quantized_decode_attention_ref(q, kc, vc, ks, vs, lens,
                                          window=window, block_t=16)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_gqa_head_fold():
    """H query heads sharing KV groups: folding [B, 1, H, dh] into
    (B*KV, G, dh) kernel rows must keep each group's queries attending
    its own KV stream — checked against a per-head einsum oracle."""
    b, h, kv, dh, t = 2, 8, 2, 16, 32
    q, kc, vc, ks, vs, lens = _case(b, h, kv, dh, t, 8, seed=3)
    out = np.asarray(quantized_decode_attention(q, kc, vc, ks, vs, lens,
                                                block_t=16))
    kf = np.asarray(kc, np.float32) * np.asarray(ks)[..., None]
    vf = np.asarray(vc, np.float32) * np.asarray(vs)[..., None]
    g = h // kv
    scale = 1.0 / np.sqrt(dh)
    for bi in range(b):
        ln = int(lens[bi])
        for hi in range(h):
            kvh = hi // g
            s = (np.asarray(q)[bi, 0, hi] @ kf[bi, :ln, kvh].T) * scale
            p = np.exp(s - s.max())
            p /= p.sum()
            want = p @ vf[bi, :ln, kvh]
            np.testing.assert_allclose(out[bi, 0, hi], want,
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("grow", [16, 96])
def test_cache_bucket_padding_is_attention_invisible(grow):
    """Growing the cache bucket around identical live entries must not
    change the output by a single bit: padded tiles are fully masked,
    and a fully-masked tile's online-softmax update is an exact no-op
    (the hypothesis-driven version lives in test_properties.py)."""
    t = 32
    q, kc, vc, ks, vs, lens = _case(2, 4, 2, 16, t, 8, seed=grow)
    pad = [(0, 0), (0, grow), (0, 0), (0, 0)]
    out = quantized_decode_attention(q, kc, vc, ks, vs, lens, block_t=16)
    out_pad = quantized_decode_attention(
        q, jnp.pad(kc, pad), jnp.pad(vc, pad),
        jnp.pad(ks, pad[:-1]), jnp.pad(vs, pad[:-1]), lens, block_t=16)
    assert np.array_equal(np.asarray(out), np.asarray(out_pad))


def test_raw_16bit_container_is_exact():
    """b_kv >= 16 stores the raw cache with ones scales through the same
    kernel: dequantization is then x * 1.0, so the quantized path must
    equal unquantized flash-decoding exactly."""
    rng = np.random.default_rng(9)
    b, h, kv, dh, t = 2, 4, 2, 16, 32
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    ones = jnp.ones(k.shape[:-1], jnp.float32)
    lens = jnp.asarray([t, t // 2], jnp.int32)
    out = quantized_decode_attention(q, k, v, ones, ones, lens,
                                     block_t=16)
    want = quantized_decode_attention(q, k * 1.0, v * 1.0, ones, ones,
                                      lens, block_t=16)
    assert np.array_equal(np.asarray(out), np.asarray(want))
    assert np.isfinite(np.asarray(out)).all()
