"""Dynamic-environment subsystem (DESIGN.md §9): process traces,
seeded determinism, state application, and quantized state keys."""

import math

import numpy as np
import pytest

from repro.core.cost_model import SystemParams
from repro.env import (Battery, Environment, MarkovLink, RayleighLink,
                       ThermalThrottle, TraceReplay)
from repro.env.presets import (PROFILE_FMAX, constant, edge_day,
                               profile_replay, rayleigh_fading, wifi_markov)

BASE = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [wifi_markov, rayleigh_fading, edge_day])
def test_same_seed_identical_trace(make):
    a, b = make(seed=13), make(seed=13)
    np.testing.assert_array_equal(a.link_trace, b.link_trace)
    np.testing.assert_array_equal(a.f_cap_trace, b.f_cap_trace)
    np.testing.assert_array_equal(a.soc_trace, b.soc_trace)
    np.testing.assert_array_equal(a.temp_trace, b.temp_trace)


@pytest.mark.parametrize("make", [wifi_markov, rayleigh_fading])
def test_different_seed_different_trace(make):
    a, b = make(seed=13), make(seed=14)
    assert not np.array_equal(a.link_trace, b.link_trace)


def test_processes_use_independent_streams():
    """The link draw count must not perturb the battery/thermal traces:
    each process gets its own spawned child stream."""
    with_link = edge_day(seed=3)
    without = Environment(seed=3, dt_s=with_link.dt_s,
                          horizon_s=with_link.horizon_s,
                          battery=Battery(capacity_j=40.0 * 90.0,
                                          drain_w=15.0, soc0=0.5))
    np.testing.assert_array_equal(with_link.soc_trace, without.soc_trace)


# ---------------------------------------------------------------------------
# individual processes
# ---------------------------------------------------------------------------

def test_markov_link_states_and_validation():
    link = MarkovLink(rates_bps=(1e6, 1e5),
                      transition=((0.9, 0.1), (0.2, 0.8)))
    trace = link.realize(np.random.default_rng(0), 200, 0.5)
    assert set(trace) <= {1e6, 1e5}
    assert trace[0] == 1e6              # starts in init_state
    with pytest.raises(ValueError):
        MarkovLink(rates_bps=(1e6, 1e5),
                   transition=((0.9, 0.2), (0.2, 0.8)))  # rows don't sum
    with pytest.raises(ValueError):
        MarkovLink(rates_bps=(1e6,), transition=((1.0, 0.0),))  # not square


def test_rayleigh_block_structure():
    link = RayleighLink(bandwidth_hz=5e6, mean_snr=8.0, coherence_s=2.0)
    trace = link.realize(np.random.default_rng(1), 40, 0.5)
    assert (trace > 0).all()
    # 40 steps x 0.5 s / 2 s coherence = 10 blocks of 4 equal samples
    blocks = trace.reshape(10, 4)
    assert (blocks == blocks[:, :1]).all()
    assert len(np.unique(blocks[:, 0])) > 1


def test_trace_replay_clamps_last_value():
    replay = TraceReplay(values=(2.0, 1.0), dwell_s=1.0)
    trace = replay.realize(None, 8, 0.5)
    np.testing.assert_array_equal(trace, [2, 2, 1, 1, 1, 1, 1, 1])


def test_battery_monotone_and_clipped():
    soc = Battery(capacity_j=10.0, drain_w=2.0, soc0=0.5).realize(
        None, 10, 1.0)
    assert (np.diff(soc) <= 0).all()
    assert soc[0] == 0.5 and soc[-1] == 0.0   # hits empty, never negative


def test_thermal_throttle_heats_up_and_derates():
    th = ThermalThrottle(tau_s=5.0)
    temp = th.temperature(100, 1.0)
    assert temp[0] < temp[-1] <= th.t_peak_c + 1e-9
    caps = th.cap_for(temp)
    assert caps[0] == th.f_full_hz          # cold: uncapped
    assert caps[-1] < th.f_full_hz          # hot: derated
    assert (caps >= th.f_floor_hz - 1e-9).all()
    # explicit map: below throttle, midway, above max
    np.testing.assert_allclose(
        th.cap_for(np.array([25.0, 80.0, 95.0])),
        [th.f_full_hz,
         th.f_full_hz - 0.5 * (th.f_full_hz - th.f_floor_hz),
         th.f_floor_hz])


# ---------------------------------------------------------------------------
# environment composition
# ---------------------------------------------------------------------------

def test_identity_environment_is_constant_and_neutral():
    env = constant()
    assert env.is_constant()
    s = env.state_at(12.3)
    assert s.apply(BASE) == BASE
    assert s.energy_scale == 1.0 and s.battery_soc == 1.0


def test_state_at_clamps_to_horizon():
    env = profile_replay(("high", "low"), dwell_s=5.0)
    assert env.state_at(-1.0).f_cap_hz == PROFILE_FMAX["high"]
    assert env.state_at(1e9).f_cap_hz == PROFILE_FMAX["low"]


def test_apply_caps_frequency_and_sets_link():
    env = Environment(
        seed=0, dt_s=1.0, horizon_s=4.0,
        link=TraceReplay(values=(5e5,), dwell_s=1.0),
        f_cap=TraceReplay(values=(1.2e9,), dwell_s=1.0))
    p = env.state_at(0.0).apply(BASE)
    assert p.f_max == 1.2e9 and p.link_bps == 5e5
    # a cap above the hardware maximum never *raises* f_max
    env2 = Environment(seed=0, dt_s=1.0, horizon_s=4.0,
                       f_cap=TraceReplay(values=(9.9e9,), dwell_s=1.0))
    assert env2.state_at(0.0).apply(BASE).f_max == BASE.f_max


def test_battery_energy_scale_derates_below_reserve():
    env = Environment(seed=0, dt_s=1.0, horizon_s=10.0,
                      battery=Battery(capacity_j=10.0, drain_w=1.0,
                                      soc0=0.5),
                      battery_reserve_soc=0.25, battery_min_scale=0.25)
    assert env.state_at(0.0).energy_scale == 1.0        # above reserve
    late = env.state_at(9.0)                            # soc far below
    assert late.battery_soc < 0.25
    assert 0.25 <= late.energy_scale < 1.0


def test_quantized_key_buckets_jitter_and_separates_regimes():
    env = constant()
    s = env.state_at(0.0)
    import dataclasses
    a = dataclasses.replace(s, link_bps=1.00e6, f_cap_hz=1.20e9)
    b = dataclasses.replace(s, link_bps=1.05e6, f_cap_hz=1.23e9)  # jitter
    c = dataclasses.replace(s, link_bps=1.0e5, f_cap_hz=0.6e9)   # regime
    assert a.quantize().key() == b.quantize().key()
    assert a.quantize().key() != c.quantize().key()
    # quantization keeps the applied view within a bucket of the truth
    pa = a.quantize().apply(BASE)
    assert abs(pa.f_max - 1.2e9) <= 0.5e8
    assert 0.7 <= pa.link_bps / 1.0e6 <= 1.5
