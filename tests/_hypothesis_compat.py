"""Soft import of hypothesis for the property-based tests.

``hypothesis`` is an optional dev dependency.  Importing it at module top
level made ``pytest -x -q`` fail at *collection* on a bare environment,
taking every non-property test in the module down with it.  Test modules
import ``given``/``settings``/``st`` from here instead: with hypothesis
installed this is a plain re-export; without it, ``@given(...)`` turns the
decorated test into a skip (same visible outcome as
``pytest.importorskip("hypothesis")``, but scoped to the property tests
only) and ``st``/``settings`` become inert stand-ins so strategy
expressions at module scope still evaluate.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any attribute access / call chain (st.floats(...)...)."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
