"""Unified tracing + metrics layer (DESIGN.md §14): Chrome trace-event
schema validity, deterministic-clock byte stability, histogram bucket
properties, the free no-op path, and — the house invariant — bitwise
identity of traced vs untraced decode."""

import json
import pathlib
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.obs import (LATENCY_BUCKETS_S, NULL_METRICS, NULL_TRACER,
                       Histogram, MetricsRegistry, ReportBase, TickClock,
                       Tracer, to_jsonable, validate_chrome_trace)
from repro.runtime import CompiledForwardCache, DecodeEngine, QosClass

from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

TOOLS = pathlib.Path(__file__).resolve().parent.parent / "tools"
SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
QOS = QosClass("interactive", t0=3.5, e0=2.0)


def _demo_tracer() -> Tracer:
    """A small deterministic trace: nested spans + an instant."""
    tr = Tracer(clock=TickClock())
    with tr.span("outer", qos="interactive", n=4):
        with tr.span("inner"):
            tr.instant("mark", rid=0)
        with tr.span("inner"):
            pass
    return tr


# ---------------------------------------------------------------- trace


def test_trace_schema_valid_and_loadable(tmp_path):
    tr = _demo_tracer()
    path = tmp_path / "t.json"
    tr.write(path)
    obj = json.loads(path.read_text(encoding="utf-8"))
    assert validate_chrome_trace(obj) == []
    evs = obj["traceEvents"]
    # required keys on every event, integer microsecond timestamps
    for ev in evs:
        for k in ("name", "ph", "ts", "pid", "tid"):
            assert k in ev
        assert isinstance(ev["ts"], int)
    # balanced B/E: 3 spans -> 3 B + 3 E, plus one instant
    assert sum(e["ph"] == "B" for e in evs) == 3
    assert sum(e["ph"] == "E" for e in evs) == 3
    assert sum(e["ph"] == "i" for e in evs) == 1
    # monotone non-decreasing within the lane
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    # args survive where given
    assert evs[0]["args"] == {"qos": "interactive", "n": 4}


@pytest.mark.parametrize("mutate,needle", [
    (lambda evs: evs.append({"name": "x", "ph": "E", "ts": 10 ** 9,
                             "pid": 1, "tid": 0}), "matching"),
    (lambda evs: evs.pop(), "unclosed"),
    (lambda evs: evs[0].pop("ts"), "missing"),
    (lambda evs: evs[0].update(ts=10 ** 12), "decreas"),
    (lambda evs: evs[0].update(ph="Z"), "phase"),
])
def test_validator_catches_malformed_traces(mutate, needle):
    obj = _demo_tracer().to_chrome_trace()
    mutate(obj["traceEvents"])
    problems = validate_chrome_trace(obj)
    assert problems, "validator accepted a malformed trace"
    assert any(needle in p for p in problems), problems


def test_validator_rejects_non_envelope():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"events": []}) != []


def test_tick_clock_traces_are_byte_stable(tmp_path):
    """Same instrumentation under the injected deterministic clock ⇒
    byte-identical trace files (the test-trace golden contract)."""
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    _demo_tracer().write(a)
    _demo_tracer().write(b)
    assert a.read_bytes() == b.read_bytes()


def test_tracer_thread_safety():
    tr = Tracer(clock=TickClock())

    def emit(tid):
        for i in range(200):
            with tr.span("w", tid=tid, i=i):
                pass

    threads = [threading.Thread(target=emit, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events) == 4 * 200 * 2
    assert validate_chrome_trace(tr.to_chrome_trace()) == []


# ------------------------------------------------------------- metrics


def test_histogram_rejects_bad_edges():
    with pytest.raises(ValueError):
        Histogram(buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram(buckets=())


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1e4,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=50))
def test_histogram_bucket_properties(values):
    """Counts conserve mass, land in the right half-open bucket, and the
    mean matches the observed values."""
    h = Histogram(buckets=LATENCY_BUCKETS_S)
    for v in values:
        h.observe(v)
    assert sum(h.counts) == len(values)
    assert h.count == len(values)
    edges = list(h.buckets)
    for v in values:
        # v belongs in the first bucket whose edge is >= v (bisect_left
        # on the right-closed edges); recompute independently
        idx = next((i for i, e in enumerate(edges) if v <= e), len(edges))
        assert h.counts[idx] >= 1
    assert h.mean == pytest.approx(sum(values) / len(values))


def test_registry_labels_and_kind_conflicts():
    m = MetricsRegistry()
    m.counter("serve.requests", qos="a").inc(2)
    m.counter("serve.requests", qos="b").inc()
    m.counter("serve.requests", qos="a").inc()     # same series
    m.gauge("live", engine="x").set(3.5)
    with pytest.raises(ValueError):
        m.gauge("serve.requests", qos="a")         # kind conflict
    snap = m.snapshot()
    series = {tuple(sorted(s["labels"].items())): s
              for s in snap["serve.requests"]["series"]}
    assert series[(("qos", "a"),)]["value"] == 3
    assert series[(("qos", "b"),)]["value"] == 1
    assert snap["live"]["kind"] == "gauge"
    json.dumps(snap)                               # snapshot is JSON-clean


def test_registry_write(tmp_path):
    m = MetricsRegistry()
    m.histogram("lat", engine="e").observe(0.01)
    path = tmp_path / "m.json"
    m.write(path)
    loaded = json.loads(path.read_text(encoding="utf-8"))
    assert loaded["lat"]["kind"] == "histogram"


# -------------------------------------------------------- no-op layer


def test_null_singletons_are_free_and_shared():
    assert not NULL_TRACER.enabled and not NULL_METRICS.enabled
    s1 = NULL_TRACER.span("a", qos="x")
    s2 = NULL_TRACER.span("b", n=3)
    assert s1 is s2                      # one preallocated span object
    with s1:
        pass
    assert NULL_TRACER.instant("i") is None
    assert len(NULL_TRACER.events) == 0  # nothing ever buffered
    c = NULL_METRICS.counter("x", qos="a")
    assert c is NULL_METRICS.histogram("y") is NULL_METRICS.gauge("z")
    c.inc(); c.observe(1.0); c.set(2.0)  # all absorbed


def test_engines_default_to_null_obs():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                       max_batch=2, max_new_tokens=2)
    assert eng.tracer is NULL_TRACER
    assert eng.metrics is NULL_METRICS


# ------------------------------------------- traced == untraced decode


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _decode_once(model, params, cache, tracer, metrics):
    eng = DecodeEngine(model, params, SYSP, classes=[QOS], auto=False,
                       max_batch=2, max_new_tokens=4,
                       compile_cache=cache, tracer=tracer, metrics=metrics)
    eng.set_operating_point(QOS.name, 8, 8)
    rng = np.random.default_rng(5)
    for i in range(4):
        toks = rng.integers(0, model.cfg.vocab_size,
                            size=int(rng.integers(4, 12))).astype(np.int32)
        eng.submit(toks, QOS.name, max_new_tokens=2 + i % 3,
                   arrival_s=0.05 * i)
    responses = eng.drain()
    return [np.asarray(r.tokens)
            for r in sorted(responses, key=lambda r: r.request_id)]


def test_traced_decode_bitwise_identical(qwen):
    """Instrumentation observes the run without perturbing it: the same
    stream decodes to bit-identical tokens with tracing on and off, and
    the trace it leaves behind is schema-valid with the full
    admission -> prefill -> chunk -> retirement story."""
    _, model, params = qwen
    cache = CompiledForwardCache()
    plain = _decode_once(model, params, cache, NULL_TRACER, NULL_METRICS)
    tr, m = Tracer(), MetricsRegistry()
    traced = _decode_once(model, params, cache, tr, m)
    assert len(plain) == len(traced)
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a, b)

    assert validate_chrome_trace(tr.to_chrome_trace()) == []
    names = {e["name"] for e in tr.events}
    assert {"decode.admit", "decode.prefill", "decode.chunk",
            "decode.retire"} <= names
    snap = m.snapshot()
    tokens = sum(s["value"] for s in snap["decode.tokens"]["series"])
    assert tokens == sum(len(t) for t in traced)


def test_compile_events_keyed_plan_bucket(qwen):
    """Cold compiles surface as xla.compile spans keyed (plan, bucket)
    and land in the compile.seconds histogram; warm runs add none."""
    _, model, params = qwen
    cache = CompiledForwardCache()
    tr, m = Tracer(), MetricsRegistry()
    _decode_once(model, params, cache, tr, m)
    compiles = [e for e in tr.events
                if e["name"] == "xla.compile" and e["ph"] == "B"]
    assert compiles
    for ev in compiles:
        assert ev["args"]["plan"] and ev["args"]["bucket"]
    assert "compile.seconds" in m.snapshot()
    # warm: same cache, fresh tracer -> no compile spans at all
    tr2 = Tracer()
    _decode_once(model, params, cache, tr2, NULL_METRICS)
    assert not any(e["name"] == "xla.compile" for e in tr2.events)


# ------------------------------------------------------------ reports


def test_report_base_to_dict_json():
    import dataclasses

    @dataclasses.dataclass
    class R(ReportBase):
        n: int
        ratio: np.float64
        classes: tuple

    r = R(n=3, ratio=np.float64(0.5), classes=({"qos": "a"},))
    d = r.to_dict()
    assert d == {"n": 3, "ratio": 0.5, "classes": [{"qos": "a"}]}
    assert json.loads(r.to_json()) == d
    assert to_jsonable({1: np.int32(2)}) == {"1": 2}


# ---------------------------------------------------------- CLI smoke


def test_trace_summary_cli(tmp_path):
    path = tmp_path / "t.json"
    _demo_tracer().write(path)
    env_cmd = [sys.executable, str(TOOLS / "trace_summary.py"), str(path)]
    out = subprocess.run(env_cmd, capture_output=True, text=True,
                         timeout=60)
    assert out.returncode == 0, out.stderr
    assert "outer" in out.stdout and "inner" in out.stdout
    assert "per-QoS-class" in out.stdout      # qos arg present on outer

    ok = subprocess.run(env_cmd + ["--validate"], capture_output=True,
                        text=True, timeout=60)
    assert ok.returncode == 0 and "OK" in ok.stdout

    bad = tmp_path / "bad.json"
    obj = _demo_tracer().to_chrome_trace()
    obj["traceEvents"].pop()                  # unclosed span
    bad.write_text(json.dumps(obj), encoding="utf-8")
    rc = subprocess.run([sys.executable, str(TOOLS / "trace_summary.py"),
                         str(bad), "--validate"],
                        capture_output=True, text=True, timeout=60)
    assert rc.returncode == 1 and "INVALID" in rc.stdout
