"""Batched co-inference engine (DESIGN.md §7): bitwise parity with the
sequential path, codesign-cache behavior, and mixed-QoS accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CodesignCache,
                           CoInferenceEngine, QosClass)

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
CLASSES = [
    QosClass("realtime", t0=1.10, e0=0.9),
    QosClass("interactive", t0=1.30, e0=1.5),
    QosClass("batch", t0=2.50, e0=4.0),
]


def _model(arch="stablelm-3b"):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _mixed_requests(eng, cfg, n=9, seed=0):
    """Round-robin classes, varying sequence lengths; returns id -> req."""
    rng = np.random.default_rng(seed)
    sent = {}
    for i in range(n):
        qos = CLASSES[i % len(CLASSES)].name
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 17)),
                            dtype=np.int64)
        sent[eng.submit(toks, qos)] = (toks, qos)
    return sent


@pytest.mark.parametrize("path", ["fake", "kernel"])
def test_batched_bitwise_identical_to_sequential(path):
    cfg, model, params = _model("qwen2-0.5b" if path == "kernel"
                                else "stablelm-3b")
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=4, path=path)
    sent = _mixed_requests(eng, cfg)
    responses = eng.drain()
    assert len(responses) == len(sent)

    seq = CoInferenceEngine(model, params, SYSP, path=path,
                            cache_weights=True)
    for r in responses:
        toks, qos = sent[r.request_id]
        sol = eng.solution_for(qos)
        seq.configure(sol.b_hat, sol.f, sol.f_server)
        want, _ = seq.serve_batch(
            {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      np.asarray(want[0]))


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_ragged_batch_padding_cannot_change_uplink_scale(seed):
    """Regression: a short request padded next to a longer one must keep
    its own per-request absmax for b_emb quantization — padding positions
    are zeroed before transport, so batched logits stay bitwise equal to
    sequential for *every* seed, not by luck of the draw."""
    cfg, model, params = _model()
    eng = BatchedCoInferenceEngine(model, params, SYSP,
                                   classes=[CLASSES[1]], max_batch=2)
    rng = np.random.default_rng(seed)
    short = rng.integers(0, cfg.vocab_size, size=6)
    long = rng.integers(0, cfg.vocab_size, size=16)
    rid_short = eng.submit(short, CLASSES[1].name)
    eng.submit(long, CLASSES[1].name)
    responses = {r.request_id: r for r in eng.drain()}
    assert responses[rid_short].logits.shape[0] == 6

    seq = CoInferenceEngine(model, params, SYSP)
    sol = eng.solution_for(CLASSES[1].name)
    seq.configure(sol.b_hat, sol.f, sol.f_server)
    want, stats = seq.serve_batch(
        {"tokens": jnp.asarray(short, jnp.int32)[None]})
    np.testing.assert_array_equal(
        np.asarray(responses[rid_short].logits), np.asarray(want[0]))
    # and its reported uplink bytes are the request's own, not a padded share
    assert responses[rid_short].stats.emb_bytes == stats.emb_bytes


def test_codesign_cache_hit_miss():
    cfg, model, params = _model()
    cache = CodesignCache()
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   codesign_cache=cache)
    # one miss per distinct (T0, E0); no per-request solves
    assert cache.misses == len(CLASSES)
    assert cache.hits == 0
    for i in range(12):
        eng.submit(np.arange(8), CLASSES[i % 3].name)
    eng.drain()
    assert cache.misses == len(CLASSES)  # serving never re-solved (P1)

    # a second engine sharing the cache resolves every class from it
    eng2 = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                    codesign_cache=cache)
    assert cache.hits == len(CLASSES)
    assert cache.misses == len(CLASSES)
    for c in CLASSES:
        assert eng2.solution_for(c.name) == eng.solution_for(c.name)
    # report() attributes each engine only its own hits/misses, not the
    # shared cache's cumulative counters
    assert eng.report().codesign_misses == len(CLASSES)
    assert eng.report().codesign_hits == 0
    assert eng2.report().codesign_misses == 0
    assert eng2.report().codesign_hits == len(CLASSES)


def test_codesign_cache_keys_on_numbers_not_names():
    cache = CodesignCache()
    a = QosClass("a", t0=1.3, e0=1.5)
    b = QosClass("b", t0=1.3, e0=1.5)
    s1 = cache.solve(30.0, SYSP, a, b_max=16)
    s2 = cache.solve(30.0, SYSP, b, b_max=16)
    assert s1 == s2
    assert cache.misses == 1 and cache.hits == 1
    # different hardware -> different entry
    cache.solve(30.0, SystemParams(n_flop_agent=3.2e10,
                                   n_flop_server=1.92e11), a, b_max=16)
    assert cache.misses == 2


def test_mixed_qos_never_shares_a_batch_and_respects_qos():
    cfg, model, params = _model()
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=8)
    sent = _mixed_requests(eng, cfg, n=12)
    responses = eng.drain()

    # every batch is single-class, within max_batch, billed at its own b̂
    for b in eng.batch_history:
        assert b.qos in {c.name for c in CLASSES}
        assert 1 <= b.batch_size <= 8
        sol = eng.solution_for(b.qos)
        assert b.b_hat == sol.b_hat
        assert b.f == sol.f and b.f_server == sol.f_server
        assert 0.0 < b.occupancy <= 1.0

    # per-request accounting carries the request's own class configuration,
    # and that configuration satisfies the class's (T0, E0) on the nominal
    # per-request workload
    by_name = {c.name: c for c in CLASSES}
    for r in responses:
        _, qos = sent[r.request_id]
        assert r.stats.qos == qos
        sol = eng.solution_for(qos)
        assert r.stats.b_hat == sol.b_hat
        c = by_name[qos]
        assert sol.delay <= c.t0 * (1 + 1e-6)
        assert sol.energy <= c.e0 * (1 + 1e-6)
        assert r.stats.queue_wait_s >= 0.0
        assert r.stats.total_delay_s == pytest.approx(
            r.stats.queue_wait_s + r.stats.batch_delay_s)


def test_fifo_order_and_max_batch():
    cfg, model, params = _model()
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=2)
    ids = [eng.submit(np.arange(8), "realtime") for _ in range(5)]
    first = eng.step()
    assert [r.request_id for r in first] == ids[:2]
    rest = eng.drain()
    assert [r.request_id for r in rest] == ids[2:]
    assert [b.batch_size for b in eng.batch_history] == [2, 2, 1]


def test_report_aggregates():
    cfg, model, params = _model()
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=4)
    _mixed_requests(eng, cfg, n=8)
    eng.drain()
    rep = eng.report()
    assert rep.requests_served == 8
    assert rep.batches_served == len(eng.batch_history)
    assert rep.mean_batch_size == pytest.approx(8 / rep.batches_served)
    assert 0.0 < rep.mean_occupancy <= 1.0
    assert rep.total_delay_s > 0.0
    assert rep.throughput_rps == pytest.approx(8 / rep.total_delay_s)
    assert rep.total_energy_j == pytest.approx(
        sum(b.energy_j for b in eng.batch_history))
    # the virtual clock is the sum of batch delays (all arrivals at t=0)
    assert rep.total_delay_s == pytest.approx(
        sum(b.batch_delay_s for b in eng.batch_history))


def test_submit_validation():
    cfg, model, params = _model()
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES)
    with pytest.raises(KeyError):
        eng.submit(np.arange(4), "no-such-class")
    with pytest.raises(ValueError):
        eng.submit(np.zeros((0,)), "realtime")
    with pytest.raises(ValueError):
        BatchedCoInferenceEngine(
            model, params, SYSP,
            classes=[QosClass("impossible", t0=1e-9, e0=1e-9)])


def test_infeasible_class_cached_as_none():
    cache = CodesignCache()
    bad = QosClass("bad", t0=1e-9, e0=1e-9)
    assert cache.solve(30.0, SYSP, bad, b_max=16) is None
    assert cache.solve(30.0, SYSP, bad, b_max=16) is None
    assert cache.misses == 1 and cache.hits == 1
