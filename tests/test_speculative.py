"""Speculative draft/verify decode (DESIGN.md §16): bitwise parity with
the sequential reference across (b_draft, b_kv, plan) including
mid-stream cancellation, longest-accepted-prefix rollback correctness at
every rejection position, and the fused spec-round compile-count bound.

The parity matrix is the PR's core claim: the draft model only ever
*proposes* — the verify chain commits exactly the reference's tokens and
cache entries, so changing the draft bit-width can change throughput but
never a single delivered bit.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.core.quantization import QuantPlan
from repro.kernels.bucketing import seq_ladder
from repro.models.registry import build_model
from repro.runtime import (CompiledForwardCache, QosClass,
                           SpeculativeDecodeEngine,
                           greedy_decode_reference)
from repro.runtime.decode_engine import _SPEC_MAX_K, _build_spec_verify

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
QOS = QosClass("interactive", t0=3.5, e0=2.0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_split3():
    cfg = dataclasses.replace(get_smoke("qwen2-0.5b"), split_layer=3)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def shared_cache():
    """One compile cache for the whole module: the fused spec-round
    executable is keyed on (cfg, batch, bucket, b_kv) — b_draft selects
    a weight *argument* and k is a runtime scalar — so the entire
    (b_draft, k) matrix reuses the same executables."""
    return CompiledForwardCache()


def _ragged_traffic(cfg, n, seed, max_prompt=20, max_new=6):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, max_prompt + 1)))
        out.append((toks.astype(np.int32),
                    int(rng.integers(1, max_new + 1)), 0.05 * i))
    return out


def _spec_engine(model, params, target, b_kv, b_draft, k, cache, *,
                 max_batch=3, max_new=6):
    eng = SpeculativeDecodeEngine(
        model, params, SYSP, classes=[QOS], auto=False,
        max_batch=max_batch, max_new_tokens=max_new,
        draft_bits=b_draft, lookahead=k, compile_cache=cache)
    eng.set_operating_point(QOS.name, target, b_kv, b_draft=b_draft,
                            k=k)
    return eng


def _assert_parity(model, params, target, b_kv, b_draft, k, cache, *,
                   n=6):
    """Speculative decode == the non-batched sequential reference, token
    for token, for every request in a ragged stream."""
    eng = _spec_engine(model, params, target, b_kv, b_draft, k, cache)
    prompts = {}
    for toks, n_new, t in _ragged_traffic(model.cfg, n, seed=3):
        prompts[eng.submit(toks, QOS.name, max_new_tokens=n_new,
                           arrival_s=t)] = (toks, n_new)
    responses = eng.drain()
    assert len(responses) == n
    for r in responses:
        toks, n_new = prompts[r.request_id]
        assert len(r.tokens) == n_new
        assert r.b_kv == b_kv
        ref = greedy_decode_reference(model, eng.class_params(QOS.name),
                                      toks, n_new, b_kv=b_kv,
                                      compile_cache=cache)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)
    st = eng.spec_stats()
    assert st.rounds > 0
    assert 0.0 <= st.acceptance_rate <= 1.0
    return eng


# ---------------------------------------------------------------------------
# parity matrix: draft rungs x cache rungs x plans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b_draft", [2, 4, 8])
@pytest.mark.parametrize("b_kv", [4, 8, 16])
def test_spec_parity_matrix(qwen, shared_cache, b_draft, b_kv):
    """The full (b_draft, b_kv) grid delivers the reference stream
    bitwise — draft fidelity moves acceptance, never content."""
    _, model, params = qwen
    _assert_parity(model, params, 8, b_kv, b_draft, 4, shared_cache)


@pytest.mark.parametrize("k", [1, 2, _SPEC_MAX_K])
def test_spec_parity_lookahead_extremes(qwen, shared_cache, k):
    """k = 1 (single-draft rounds) and k = _SPEC_MAX_K (full block)
    exercise the while-loop bounds; both must stay bitwise."""
    _, model, params = qwen
    _assert_parity(model, params, 8, 8, 4, k, shared_cache)


@pytest.mark.parametrize("bits,b_kv", [((4, 8, 12), 8), ((4, 4, 6), 4)])
def test_spec_parity_mixed_plan(qwen_split3, bits, b_kv):
    """Per-layer mixed target plans change only the verify weight tree;
    the draft stays a uniform rung — parity must survive the mix."""
    _, model, params = qwen_split3
    plan = QuantPlan.from_layer_bits(list(bits))
    _assert_parity(model, params, plan, b_kv, 4, 3,
                   CompiledForwardCache())


def test_spec_cancel_mid_stream(qwen, shared_cache):
    """cancel() mid-round frees the slot and the survivors still decode
    bitwise what they would have alone — a dead request must not perturb
    its former batch-mates' drafts or verifications."""
    _, model, params = qwen
    eng = _spec_engine(model, params, 8, 8, 4, 4, shared_cache,
                       max_batch=2, max_new=10)
    rng = np.random.default_rng(5)
    prompts = {}
    for i in range(3):
        toks = rng.integers(0, model.cfg.vocab_size, size=20 + i)
        prompts[eng.submit(toks, QOS.name, arrival_s=0.0)] = toks
    rids = list(prompts)
    # two in flight, one queued; short rounds (1 draft + 1 verify per
    # step, at most 2 delivered) so nobody runs to budget first
    for _ in range(3):
        eng.step(max_decode_steps=2)
    assert eng.in_flight == 2
    dead = eng.cancel(rids[0])
    assert dead is not None and dead.cancelled
    assert len(dead.tokens) < eng.max_new_tokens
    assert eng.cancel(rids[0]) is None       # already retired
    survivors = {r.request_id: r for r in eng.drain()}
    assert set(survivors) == set(rids[1:])
    for rid, r in survivors.items():
        assert not r.cancelled
        ref = greedy_decode_reference(model, eng.class_params(QOS.name),
                                      prompts[rid], len(r.tokens),
                                      b_kv=8, compile_cache=shared_cache)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)
    # the cancelled prefix it did emit is also the reference's prefix
    if len(dead.tokens):
        ref = greedy_decode_reference(model, eng.class_params(QOS.name),
                                      prompts[rids[0]], len(dead.tokens),
                                      b_kv=8, compile_cache=shared_cache)
        np.testing.assert_array_equal(np.asarray(dead.tokens), ref)
    assert eng.report().cancelled == 1


# ---------------------------------------------------------------------------
# rollback correctness at every rejection position
# ---------------------------------------------------------------------------

def test_spec_rollback_at_rejection_positions(qwen):
    """Drive the verify chain with crafted draft blocks that diverge at
    position j ∈ {0, 1, k-1} (and never, for the bonus-token path): the
    delivered block must be the accepted prefix plus the correction, and
    the cache buffers must be BITWISE the sequential reference's state
    after that many tokens — truncated exactly, no stale entries (the
    honest draft chain can't produce these blocks on demand, which is
    why the builder stays unit-testable on its own)."""
    cfg, model, params = qwen
    b_kv, k, budget = 8, 4, 8
    cache = CompiledForwardCache()
    prompt = np.random.default_rng(9).integers(
        0, cfg.vocab_size, size=12).astype(np.int32)
    full = greedy_decode_reference(model, params, prompt, budget,
                                   b_kv=b_kv, reserve_tokens=budget,
                                   compile_cache=cache)
    first, st = greedy_decode_reference(model, params, prompt, 1,
                                        b_kv=b_kv,
                                        reserve_tokens=budget,
                                        compile_cache=cache,
                                        return_state=True)
    assert first[0] == full[0]
    verify = _build_spec_verify(model, b_kv)
    for j in (0, 1, k - 1, k):               # k = all accepted (bonus)
        drafts = np.zeros((1, _SPEC_MAX_K), np.int32)
        drafts[0, :j] = full[1:j + 1]        # accepted prefix
        if j < k:                            # rejected at position j
            drafts[0, j] = (full[j + 1] + 1) % cfg.vocab_size
        out, cnt, acc, kc, vc, ks, vs, tok, pos = verify(
            params, jnp.asarray(st["k_codes"]),
            jnp.asarray(st["v_codes"]), jnp.asarray(st["k_scales"]),
            jnp.asarray(st["v_scales"]),
            jnp.asarray([st["last_token"]], jnp.int32),
            jnp.asarray([st["pos"]], jnp.int32),
            jnp.asarray([1], jnp.int32), jnp.asarray(drafts),
            jnp.asarray(k, jnp.int32),
            jnp.asarray([budget - 1], jnp.int32),
            jnp.asarray(-1, jnp.int32))
        n_out = int(np.asarray(cnt)[0])
        assert int(np.asarray(acc)[0]) == j   # accepted prefix length
        assert n_out == j + 1                 # ... plus the correction
        np.testing.assert_array_equal(np.asarray(out)[0, :n_out],
                                      full[1:j + 2])
        # the committed cache is exactly the reference's after the same
        # tokens: rejected draft entries were reverted, nothing stale
        _, want = greedy_decode_reference(model, params, prompt,
                                          1 + n_out, b_kv=b_kv,
                                          reserve_tokens=budget,
                                          compile_cache=cache,
                                          return_state=True)
        np.testing.assert_array_equal(np.asarray(kc), want["k_codes"])
        np.testing.assert_array_equal(np.asarray(vc), want["v_codes"])
        np.testing.assert_array_equal(np.asarray(ks), want["k_scales"])
        np.testing.assert_array_equal(np.asarray(vs), want["v_scales"])
        assert int(np.asarray(pos)[0]) == int(want["pos"])
        assert int(np.asarray(tok)[0]) == int(want["last_token"])


# ---------------------------------------------------------------------------
# compile-count bound
# ---------------------------------------------------------------------------

def test_spec_compile_count_bounded_and_warm_traffic_never_recompiles(
        qwen):
    cfg, model, params = qwen
    cache = CompiledForwardCache()
    classes = [QosClass("rt", t0=1.0, e0=1.0),
               QosClass("ia", t0=3.0, e0=2.0)]
    eng = SpeculativeDecodeEngine(model, params, SYSP, classes=classes,
                                  auto=False, max_batch=4,
                                  max_new_tokens=8, compile_cache=cache)
    eng.set_operating_point("rt", 4, 4, b_draft=4, k=2)
    eng.set_operating_point("ia", 8, 8, b_draft=8, k=4)
    max_prompt = 40
    warm = eng.warmup(max_prompt)
    n_kv = len({eng.b_kv_for(c.name) for c in classes})
    # prefill pairs as in plain decode, plus ONE fused spec-round
    # executable per (cache bucket, b_kv) — draft and verify ride in a
    # single dispatch, so the round budget is half the ladder x
    # {draft, verify} allowance the design reserves
    t_rungs = seq_ladder(max_prompt + 8)
    pairs = sum(1 for s in seq_ladder(max_prompt) for t in t_rungs
                if t >= s)
    bound = (pairs + len(t_rungs)) * n_kv
    assert 0 < warm <= bound
    assert bound <= (pairs + 2 * len(t_rungs)) * n_kv
    miss0 = cache.misses

    rng = np.random.default_rng(11)
    for i in range(14):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, max_prompt + 1)))
        eng.submit(toks, classes[i % 2].name,
                   max_new_tokens=int(rng.integers(1, 9)),
                   arrival_s=0.02 * i)
    responses = eng.drain()
    assert len(responses) == 14
    assert cache.misses == miss0        # warm traffic never recompiles
    assert len(cache) <= bound
    rep = eng.report()
    assert rep.compile_misses == cache.misses
    assert rep.compiled_variants == len(cache)
    assert rep.tokens_generated == sum(len(r.tokens) for r in responses)
    # the rounds actually drafted: the accounting adds up (prefill
    # emits each request's first token outside any spec round)
    st = eng.spec_stats()
    assert st.delivered == rep.tokens_generated - rep.prefills
    assert st.accepted <= st.drafted


def test_spec_engine_rejects_bad_schedule(qwen):
    _, model, params = qwen
    with pytest.raises(ValueError, match="lookahead"):
        SpeculativeDecodeEngine(model, params, SYSP, classes=[QOS],
                                auto=False, lookahead=0)
    eng = _spec_engine(model, params, 8, 8, 4, 2,
                       CompiledForwardCache())
    with pytest.raises(ValueError, match="b_draft"):
        eng.set_operating_point(QOS.name, 8, 8, b_draft=1)
    with pytest.raises(ValueError, match="lookahead"):
        eng.set_operating_point(QOS.name, 8, 8, k=_SPEC_MAX_K + 1)
