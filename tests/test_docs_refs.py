"""Every ``DESIGN.md §N`` citation in src/ and every file citation in
the documentation set (DESIGN.md, README.md, docs/ARCHITECTURE.md) must
resolve (the same check CI runs via tools/check_design_refs.py)."""

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run(root) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_design_refs.py"),
         "--root", str(root)],
        capture_output=True, text=True, timeout=60)


def test_design_refs_resolve():
    out = _run(ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK:" in out.stdout


def test_architecture_doc_exists_and_is_linked():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()
    assert "docs/ARCHITECTURE.md" in (ROOT / "README.md").read_text(
        encoding="utf-8")


def test_design_refs_catch_dangling(tmp_path):
    """The checker actually fails on a dangling section reference."""
    (tmp_path / "DESIGN.md").write_text("## §1 Only section\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text('"""See DESIGN.md §9."""\n')
    out = _run(tmp_path)
    assert out.returncode == 1
    assert "§9" in out.stdout


def test_design_refs_catch_dangling_in_docs(tmp_path):
    """§N references inside the docs themselves are validated too."""
    (tmp_path / "DESIGN.md").write_text("## §1 Only section\n")
    (tmp_path / "src").mkdir()
    (tmp_path / "README.md").write_text("See DESIGN.md §7 for details.\n")
    out = _run(tmp_path)
    assert out.returncode == 1
    assert "§7" in out.stdout


def test_file_citations_catch_dangling(tmp_path):
    """A backtick path citation to a missing file fails the check."""
    (tmp_path / "DESIGN.md").write_text(
        "## §1 Only section\nSee `core/definitely_missing.py`.\n")
    (tmp_path / "src").mkdir()
    out = _run(tmp_path)
    assert out.returncode == 1
    assert "definitely_missing.py" in out.stdout


def test_file_citations_resolve_relative_to_src_repro(tmp_path):
    """`core/x.py` resolves via src/repro/, repo-root paths directly,
    and slash-less names (placeholders like `spec.json`) are skipped."""
    (tmp_path / "DESIGN.md").write_text(
        "## §1 Only section\n"
        "Cites `core/x.py`, `tools/y.py`, and a `spec.json` placeholder.\n")
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "core" / "x.py").write_text("")
    (tmp_path / "tools").mkdir()
    (tmp_path / "tools" / "y.py").write_text("")
    out = _run(tmp_path)
    assert out.returncode == 0, out.stdout
