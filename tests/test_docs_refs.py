"""Every ``DESIGN.md §N`` citation in src/ must resolve (the same check CI
runs via tools/check_design_refs.py)."""

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_design_refs_resolve():
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_design_refs.py"),
         "--root", str(ROOT)],
        capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK:" in out.stdout


def test_design_refs_catch_dangling(tmp_path):
    """The checker actually fails on a dangling reference."""
    (tmp_path / "DESIGN.md").write_text("## §1 Only section\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text('"""See DESIGN.md §9."""\n')
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_design_refs.py"),
         "--root", str(tmp_path)],
        capture_output=True, text=True, timeout=60)
    assert out.returncode == 1
    assert "§9" in out.stdout
