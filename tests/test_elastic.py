"""Elastic re-mesh: lose half the devices mid-run, reshard, continue.

Runs in a subprocess so ``--xla_force_host_platform_device_count=8`` can be
set before jax initializes (the main test process must keep 1 device).
Both subprocess tests carry the ``slow`` marker (registered in
pyproject.toml): deselect with ``pytest -m "not slow"``.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.models.registry import build_model
from repro.launch.mesh import compat_make_mesh
from repro.optim import AdamW
from repro.parallel.sharding import default_rules
from repro.runtime import TrainConfig, Trainer

assert len(jax.devices()) == 8, jax.devices()


def make_mesh(n):
    # (data, model) over n devices, TP degree 2
    return compat_make_mesh((n // 2, 2), ("data", "model"),
                            devices=jax.devices()[:n])


def session(ckpt_dir, n_devices, steps):
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    mesh = make_mesh(n_devices)
    tr = Trainer(model, AdamW(learning_rate=1e-3), mesh,
                 TrainConfig(log_every=1),
                 ckpt=CheckpointManager(ckpt_dir, save_interval=5))
    loader = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
        vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)))
    _, hist = tr.fit(loader, steps)
    return tr, hist


with tempfile.TemporaryDirectory() as d:
    # phase 1: 8 devices (4x2 mesh)
    tr1, h1 = session(d, 8, 10)
    assert tr1.step == 10
    # "failure": only 4 devices survive -> 2x2 mesh, restore + reshard
    tr2, h2 = session(d, 4, 5)
    assert tr2.step == 15, tr2.step     # resumed from step-10 checkpoint
    losses = [h["loss"] for h in h1 + h2]
    assert all(np.isfinite(l) for l in losses)
    # training continued sensibly (loss in phase 2 not exploding)
    assert h2[-1]["loss"] < h1[0]["loss"] + 1.0
    print("ELASTIC_OK", tr2.step, f"{h1[0]['loss']:.3f}->{h2[-1]['loss']:.3f}")
"""

_DECODE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax
import numpy as np

from repro.configs import get_smoke
from repro.models.registry import build_model
from repro.runtime import greedy_decode_reference

assert len(jax.devices()) == 8, jax.devices()

MAX_NEW = 8
PROMPT = 13


def session(n_devices):
    # a fresh "process" after the re-mesh: new model object, params
    # rebuilt from the same seed, a cold compile cache, weights placed
    # on the surviving device set
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dev = jax.devices()[:n_devices][0]
    return model, jax.device_put(params, dev)


toks = (np.arange(PROMPT, dtype=np.int64) % 512).astype(np.int32)

# uninterrupted oracle, all 8 devices
model, params = session(8)
want = greedy_decode_reference(model, params, toks, MAX_NEW, b_kv=4)

# phase 1: decode 3 of 8 tokens on the full mesh, checkpoint the decode
# state (plain numpy arrays -> np.savez round-trip, like any checkpoint)
first, state = greedy_decode_reference(model, params, toks, 3, b_kv=4,
                                       reserve_tokens=MAX_NEW,
                                       return_state=True)
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "decode_state.npz")
    np.savez(path, **state)
    loaded = dict(np.load(path))

# phase 2: half the devices survive; a rebuilt session resumes the
# decode from the restored state and must land on the oracle's tokens
model2, params2 = session(4)
rest = greedy_decode_reference(model2, params2, toks, MAX_NEW - 3,
                               b_kv=4, state=loaded)
got = np.concatenate([first, rest])
assert np.array_equal(got, want), (got, want)
print("DECODE_RESUME_OK", got.tolist())
"""


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))


@pytest.mark.slow
def test_elastic_remesh_subprocess():
    out = _run_subprocess(_SCRIPT)
    assert "ELASTIC_OK" in out.stdout, (out.stdout[-2000:],
                                        out.stderr[-2000:])


@pytest.mark.slow
def test_decode_state_resumes_after_remesh_subprocess():
    """Decode-state checkpointing across an elastic re-mesh: a decode
    split by a device loss — state serialized, session rebuilt on the
    surviving devices, decode resumed — must produce bitwise the tokens
    of the uninterrupted run (DESIGN.md §12)."""
    out = _run_subprocess(_DECODE_SCRIPT)
    assert "DECODE_RESUME_OK" in out.stdout, (out.stdout[-2000:],
                                              out.stderr[-2000:])
