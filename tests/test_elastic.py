"""Elastic re-mesh: lose half the devices mid-run, reshard, continue.

Runs in a subprocess so ``--xla_force_host_platform_device_count=8`` can be
set before jax initializes (the main test process must keep 1 device).
"""

import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.models.registry import build_model
from repro.launch.mesh import compat_make_mesh
from repro.optim import AdamW
from repro.parallel.sharding import default_rules
from repro.runtime import TrainConfig, Trainer

assert len(jax.devices()) == 8, jax.devices()


def make_mesh(n):
    # (data, model) over n devices, TP degree 2
    return compat_make_mesh((n // 2, 2), ("data", "model"),
                            devices=jax.devices()[:n])


def session(ckpt_dir, n_devices, steps):
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    mesh = make_mesh(n_devices)
    tr = Trainer(model, AdamW(learning_rate=1e-3), mesh,
                 TrainConfig(log_every=1),
                 ckpt=CheckpointManager(ckpt_dir, save_interval=5))
    loader = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
        vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)))
    _, hist = tr.fit(loader, steps)
    return tr, hist


with tempfile.TemporaryDirectory() as d:
    # phase 1: 8 devices (4x2 mesh)
    tr1, h1 = session(d, 8, 10)
    assert tr1.step == 10
    # "failure": only 4 devices survive -> 2x2 mesh, restore + reshard
    tr2, h2 = session(d, 4, 5)
    assert tr2.step == 15, tr2.step     # resumed from step-10 checkpoint
    losses = [h["loss"] for h in h1 + h2]
    assert all(np.isfinite(l) for l in losses)
    # training continued sensibly (loss in phase 2 not exploding)
    assert h2[-1]["loss"] < h1[0]["loss"] + 1.0
    print("ELASTIC_OK", tr2.step, f"{h1[0]['loss']:.3f}->{h2[-1]['loss']:.3f}")
"""


def test_elastic_remesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "ELASTIC_OK" in out.stdout, (out.stdout[-2000:],
                                        out.stderr[-2000:])
