"""The serving driver must fail with a one-line error — never a
traceback — when flags are combined with an arch the requested path
cannot serve (ISSUE 5 satellite), and the fleet spec path must validate
its input the same way."""

import json
import pathlib

import pytest

from repro.launch import serve


def test_compiled_with_unsupported_arch_errors_cleanly(capsys):
    # xlstm-350m is outside the dense DecoderLM family: --compiled has
    # no embed/run_layers_window hooks to trace (DESIGN.md §10)
    rc = serve.main(["--arch", "xlstm-350m", "--smoke", "--compiled"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "--compiled" in err and "xlstm-350m" in err
    assert "Traceback" not in err


def test_unsupported_arch_errors_cleanly_without_compiled(capsys):
    # ... and the generic co-inference protocol mismatch is also a
    # clean error, not a constructor traceback
    rc = serve.main(["--arch", "xlstm-350m", "--smoke"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "run_layers" in err
    assert "Traceback" not in err


def test_unsupported_model_reason_accepts_decoder_family():
    class _Decoder:
        def embed(self):
            pass

        def run_layers(self):
            pass

        def run_layers_window(self):
            pass

    assert serve.unsupported_model_reason(_Decoder(), "x", True) is None
    assert serve.unsupported_model_reason(_Decoder(), "x", False) is None
    # no run_layers at all: unservable either way
    assert "run_layers" in serve.unsupported_model_reason(
        object(), "x", False)
    # the compiled complaint is the more specific one and wins
    assert "--compiled" in serve.unsupported_model_reason(
        object(), "x", True)
    # ... and --decode needs the KV-cache decode protocol on top
    assert "--decode" in serve.unsupported_model_reason(
        _Decoder(), "x", False, decode=True)
    # ... as does --speculative, whose complaint names its own flag
    assert "--speculative" in serve.unsupported_model_reason(
        _Decoder(), "x", False, speculative=True)


@pytest.mark.parametrize("arch", ["seamless-m4t-large-v2", "xlstm-350m"])
def test_decode_with_unsupported_arch_errors_cleanly(arch, capsys):
    # encdec/hybrid archs define prefill/decode_step but not the dense
    # [layers, batch, cache_seq, kv_heads, head_dim] KV cache the decode
    # engine batches over (DESIGN.md §12) -> clean exit 2, no traceback
    rc = serve.main(["--arch", arch, "--smoke", "--decode"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "--decode" in err and arch in err
    assert "Traceback" not in err


def test_decode_with_fcdnn_errors_cleanly(capsys):
    # fcdnn-16 ships no ModelConfig at all (it is the distortion-
    # benchmark toy); any serve invocation must fail one-line, not with
    # a build_model traceback
    rc = serve.main(["--arch", "fcdnn-16", "--smoke", "--decode"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "fcdnn-16" in err
    assert "Traceback" not in err


@pytest.mark.parametrize("payload", ["not json {", "{}",
                                     '{"agents": []}'])
def test_fleet_spec_validation_errors_cleanly(tmp_path, payload, capsys):
    spec = tmp_path / "fleet.json"
    spec.write_text(payload)
    rc = serve.main(["--smoke", "--fleet", str(spec)])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "Traceback" not in err


def test_fleet_spec_missing_file_errors_cleanly(tmp_path, capsys):
    rc = serve.main(["--smoke", "--fleet", str(tmp_path / "nope.json")])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")


@pytest.mark.parametrize("agent", [
    {"arch": "qwen2-0.5b"},                       # missing name
    {"name": "a"},                                # missing arch
    {"name": "a", "arch": "no-such-arch"},        # unknown arch
    {"name": "a", "arch": "qwen2-0.5b",
     "env_trace": "no-such-trace"},               # unknown env trace
    {"name": "a", "arch": "qwen2-0.5b",
     "sysp": {"no_such_field": 1.0}},             # bad SystemParams field
    {"name": "a", "arch": "qwen2-0.5b",
     "t0": "fast"},                               # non-numeric budget
])
def test_fleet_spec_bad_agent_entries_error_cleanly(tmp_path, agent,
                                                    capsys):
    spec = tmp_path / "fleet.json"
    spec.write_text(json.dumps({"agents": [agent]}))
    rc = serve.main(["--smoke", "--fleet", str(spec)])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error: fleet agent")
    assert "Traceback" not in err


@pytest.mark.parametrize("payload", [
    "not json {",
    '{"no_such_key": 1}',
    '{"dt_s": -0.5}',
    '{"link_outage": {"p_fail": 2.0}}',
    '{"corruption": {"typo": 0.1}}',
])
def test_chaos_spec_validation_errors_cleanly(tmp_path, payload, capsys):
    spec = tmp_path / "chaos.json"
    spec.write_text(payload)
    rc = serve.main(["--smoke", "--chaos-trace", str(spec)])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error: cannot load chaos trace")
    assert "Traceback" not in err


def test_chaos_spec_missing_file_errors_cleanly(tmp_path, capsys):
    rc = serve.main(["--smoke", "--chaos-trace",
                     str(tmp_path / "nope.json")])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")


def test_chaos_with_sequential_engine_errors_cleanly(tmp_path, capsys):
    # the sequential engine has no queue/virtual clock to supervise
    spec = tmp_path / "chaos.json"
    spec.write_text('{"corruption": {"rate": 0.1}}')
    rc = serve.main(["--smoke", "--engine", "sequential",
                     "--chaos-trace", str(spec)])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "chaos" in err and "Traceback" not in err


def test_chaos_smoke_run_prints_resilience_line(capsys):
    # the shipped example spec must keep driving a supervised smoke run
    example = pathlib.Path(__file__).resolve().parent.parent \
        / "examples" / "chaos_spec.json"
    rc = serve.main(["--smoke", "--chaos-trace", str(example)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "resilience [supervised]:" in out
    assert "tokens lost/dup=0/0" in out


@pytest.mark.parametrize("arch", ["seamless-m4t-large-v2", "xlstm-350m"])
def test_speculative_with_unsupported_arch_errors_cleanly(arch, capsys):
    # --speculative rides the decode engine's dense KV-cache protocol;
    # non-decoder archs must die with the flag's own one-liner, not a
    # SpeculativeDecodeEngine constructor traceback (DESIGN.md §16)
    rc = serve.main(["--arch", arch, "--smoke", "--speculative"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "--speculative" in err and arch in err
    assert "Traceback" not in err


def test_speculative_bad_lookahead_errors_cleanly(capsys):
    rc = serve.main(["--smoke", "--speculative", "--lookahead", "0"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "--lookahead" in err and "Traceback" not in err


def test_speculative_off_ladder_draft_bits_errors_cleanly(capsys):
    # b_draft must sit on the realizable container ladder: the draft
    # weights live in the same packed int4/int8 containers as every
    # other plan, so 3-bit drafts have nowhere to live
    rc = serve.main(["--smoke", "--speculative", "--draft-bits", "3"])
    err = capsys.readouterr().err
    assert rc == 2
    assert err.startswith("error:")
    assert "draft ladder" in err and "Traceback" not in err


def test_fleet_spec_compiled_unsupported_arch_errors_cleanly(tmp_path,
                                                             capsys):
    spec = tmp_path / "fleet.json"
    spec.write_text(json.dumps({
        "compiled": True,
        "agents": [{"name": "a", "arch": "xlstm-350m",
                    "t0": 1.0, "e0": 1.0}],
    }))
    rc = serve.main(["--smoke", "--fleet", str(spec)])
    err = capsys.readouterr().err
    assert rc == 2
    assert "compiled" in err and "xlstm-350m" in err
    assert "Traceback" not in err
