"""Property-based invariants for the serving-shape ladders
(``kernels.bucketing``), the quantization pack/unpack round-trips
(``kernels.quantize`` / ``kernels.ref`` / ``core.quantization``) — the
two pieces of pure arithmetic the decode engine's compile-count bound
and KV-cache parity rest on (DESIGN.md §10, §12) — and the codesign
solvers' contract with the cost model: a feasible solution must
actually meet its budgets under independent re-evaluation, and
loosening budgets must never worsen the bound (DESIGN.md §12, §16).

Runs under hypothesis when installed; otherwise the ``@given`` tests
skip (see ``_hypothesis_compat``) and the deterministic spot checks
below still run everywhere.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.core import codesign, cost_model
from repro.core.cost_model import SystemParams
from repro.core.quantization import (pack_int4, unpack_int4, wire_bytes)
from repro.kernels import ref
from repro.kernels.bucketing import (DEFAULT_SEQ_BASE, next_geometric,
                                     row_bucket, seq_bucket, seq_ladder)
from repro.kernels.quantize import (kv_cache_bytes, kv_dequantize,
                                    kv_levels, kv_quantize)

# ---------------------------------------------------------------------------
# bucket-ladder invariants (DESIGN.md §10)
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(s=st.integers(min_value=1, max_value=100_000))
def test_seq_bucket_covers_and_is_idempotent(s):
    b = seq_bucket(s)
    assert b >= s                       # padding never truncates
    assert seq_bucket(b) == b           # snapping is idempotent
    # tight: the next rung down would not cover s (or s is below base)
    assert b == DEFAULT_SEQ_BASE or b // 2 < s


@settings(max_examples=100, deadline=None)
@given(a=st.integers(min_value=1, max_value=100_000),
       b=st.integers(min_value=1, max_value=100_000))
def test_seq_bucket_monotone(a, b):
    lo, hi = min(a, b), max(a, b)
    assert seq_bucket(lo) <= seq_bucket(hi)


@settings(max_examples=100, deadline=None)
@given(max_s=st.integers(min_value=1, max_value=100_000))
def test_seq_ladder_geometric_and_covering(max_s):
    ladder = seq_ladder(max_s)
    assert ladder[0] == DEFAULT_SEQ_BASE
    assert ladder[-1] == seq_bucket(max_s) >= max_s
    for lo, hi in zip(ladder, ladder[1:]):
        assert hi == 2 * lo             # strictly geometric, no gaps
    # every length <= max_s snaps to a rung of this ladder: warmup over
    # the ladder precompiles everything traffic can dispatch
    assert all(seq_bucket(s) in ladder
               for s in (1, max_s // 2 or 1, max_s))


@settings(max_examples=100, deadline=None)
@given(max_a=st.integers(min_value=1, max_value=10_000),
       max_b=st.integers(min_value=1, max_value=10_000))
def test_seq_ladder_prefix_stable(max_a, max_b):
    """A longer horizon only appends rungs — it never reshuffles the
    existing ones, so growing ``warmup()`` coverage never invalidates
    already-compiled variants."""
    lo, hi = sorted((max_a, max_b))
    a, b = seq_ladder(lo), seq_ladder(hi)
    assert b[:len(a)] == a


@settings(max_examples=100, deadline=None)
@given(n=st.integers(min_value=1, max_value=1_000_000),
       base=st.integers(min_value=1, max_value=512),
       ratio=st.integers(min_value=2, max_value=5))
def test_next_geometric_minimal(n, base, ratio):
    g = next_geometric(n, base, ratio)
    assert g >= n and g >= base
    assert g == base or g // ratio < n  # the next rung down is too small


@settings(max_examples=100, deadline=None)
@given(m=st.integers(min_value=1, max_value=100_000))
def test_row_bucket_mxu_aligned(m):
    b = row_bucket(m)
    assert b >= m and b % 128 == 0
    assert b == 128 or b // 2 < m


def test_bucket_spot_checks():
    # deterministic floor so the invariants are exercised even without
    # hypothesis installed
    assert seq_bucket(1) == 16 and seq_bucket(17) == 32
    assert seq_ladder(48) == (16, 32, 64)
    assert row_bucket(129) == 256
    with pytest.raises(ValueError):
        seq_bucket(0)


# ---------------------------------------------------------------------------
# pack/unpack round-trips
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(rows=st.integers(min_value=1, max_value=8),
       cols=st.integers(min_value=1, max_value=16),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pack_int4_round_trip(rows, cols, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(rows, 2 * cols)).astype(np.int8)
    out = np.asarray(unpack_int4(pack_int4(codes)))
    np.testing.assert_array_equal(out, codes)
    assert wire_bytes(codes.size, 4) == codes.size // 2


@settings(max_examples=50, deadline=None)
@given(k2=st.integers(min_value=1, max_value=16),
       n=st.integers(min_value=1, max_value=16),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pack_int4_ref_round_trip(k2, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(2 * k2, n)).astype(np.int8)
    out = np.asarray(ref.unpack_int4_ref(ref.pack_int4_ref(codes)))
    np.testing.assert_array_equal(out, codes)


def test_pack_int4_rejects_odd_axis():
    with pytest.raises(ValueError):
        pack_int4(np.zeros((3, 5), np.int8))


# ---------------------------------------------------------------------------
# KV-cache quantization (DESIGN.md §12)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       t=st.integers(min_value=1, max_value=6),
       d=st.integers(min_value=1, max_value=8),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kv_quantize_round_trip_bounded(bits, t, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, d)).astype(np.float32) * 3.0
    codes, scales = kv_quantize(x, bits)
    codes, scales = np.asarray(codes), np.asarray(scales)
    lv = kv_levels(bits)
    assert codes.dtype == np.int8
    assert np.abs(codes).max() <= lv
    assert scales.shape == x.shape[:-1]
    # symmetric uniform quantization: error is at most half a step per
    # element (round-to-nearest), scale = absmax / levels per vector
    dq = np.asarray(kv_dequantize(codes, scales))
    np.testing.assert_allclose(dq, x, atol=float(scales.max()) / 2 + 1e-6)


@settings(max_examples=50, deadline=None)
@given(bits=st.sampled_from([4, 8]),
       d=st.integers(min_value=1, max_value=8))
def test_kv_quantize_zero_vector_is_safe(bits, d):
    x = np.zeros((3, d), np.float32)
    codes, scales = kv_quantize(x, bits)
    assert np.all(np.asarray(codes) == 0)
    assert np.all(np.asarray(scales) == 1.0)    # no divide-by-zero scale
    np.testing.assert_array_equal(np.asarray(kv_dequantize(codes, scales)),
                                  x)


@settings(max_examples=25, deadline=None)
@given(b_kv=st.sampled_from([4, 8, 16]),
       dh=st.sampled_from([8, 16]),
       len0=st.integers(min_value=1, max_value=32),
       len1=st.integers(min_value=1, max_value=32),
       grow=st.sampled_from([16, 32, 96]),
       seed=st.integers(min_value=0, max_value=2**16))
def test_cache_bucket_padding_is_attention_invisible(b_kv, dh, len0, len1,
                                                     grow, seed):
    """Growing a request's cache bucket (T -> T + grow) around identical
    live entries changes the fused decode attention output by ZERO bits:
    padded positions sit in fully-masked tiles, and a fully-masked
    tile's online-softmax update is an exact no-op (DESIGN.md §13).
    This is the invariant that lets the engine bucket each request's
    cache from its own (prompt, budget) independent of its batch-mates
    while staying bitwise-comparable to the sequential reference."""
    import jax.numpy as jnp

    from repro.kernels.decode_attn import quantized_decode_attention

    t = 32
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, t, 2, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, t, 2, dh)), jnp.float32)
    if b_kv < 16:
        kc, ks = kv_quantize(k, b_kv)
        vc, vs = kv_quantize(v, b_kv)
    else:
        kc, vc = k, v
        ks = jnp.ones(k.shape[:-1], jnp.float32)
        vs = jnp.ones(v.shape[:-1], jnp.float32)
    lens = jnp.asarray([len0, len1], jnp.int32)
    pad = [(0, 0), (0, grow), (0, 0), (0, 0)]
    out = quantized_decode_attention(q, kc, vc, ks, vs, lens, block_t=16)
    out_pad = quantized_decode_attention(
        q, jnp.pad(kc, pad), jnp.pad(vc, pad),
        jnp.pad(ks, pad[:-1]), jnp.pad(vs, pad[:-1]), lens, block_t=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_pad))


# ---------------------------------------------------------------------------
# codesign solver contract (DESIGN.md §12, §16)
# ---------------------------------------------------------------------------

# a decode-serving-shaped operating point: FLOP counts at smoke scale
# with a cache stream sized so b_kv is a live decision (kv_delay(16) =
# 0.5 s against t0 of a few seconds)
_P = SystemParams(n_flop_agent=5.0e8, n_flop_server=7.0e8,
                  kv_bytes_full=2.0e6, kv_bw_bps=4.0e6, kv_power_w=2.0)
_BUDGETS = st.tuples(st.floats(min_value=0.3, max_value=6.0),
                     st.floats(min_value=0.3, max_value=6.0))
_LAM = st.floats(min_value=0.05, max_value=5.0)


@settings(max_examples=60, deadline=None)
@given(lam=_LAM, lam_kv=_LAM, budgets=_BUDGETS)
def test_solve_decode_feasible_meets_budgets(lam, lam_kv, budgets):
    """A feasible solve_decode answer survives independent
    re-evaluation: plugging (b̂, f, f̃, b_kv) back into the cost model
    reproduces the reported delay/energy and respects (T0, E0)."""
    t0, e0 = budgets
    sol = codesign.solve_decode(lam, lam_kv, _P, t0, e0)
    if sol is None:        # infeasible corner: nothing to re-evaluate
        return
    d = float(cost_model.total_delay(sol.b_hat, sol.f, sol.f_server,
                                     _P, b_kv=sol.b_kv))
    e = float(cost_model.total_energy(sol.b_hat, sol.f, sol.f_server,
                                      _P, b_kv=sol.b_kv))
    assert sol.feasible
    assert d == pytest.approx(sol.delay, rel=1e-9)
    assert e == pytest.approx(sol.energy, rel=1e-9)
    assert d <= t0 * (1 + 1e-6) and e <= e0 * (1 + 1e-6)


@settings(max_examples=40, deadline=None)
@given(lam=_LAM, lam_kv=_LAM, budgets=_BUDGETS)
def test_solve_speculative_feasible_meets_budgets(lam, lam_kv, budgets):
    """Same contract for the speculative joint solve: the realized
    per-delivered-token round cost — draft chain, ONE batched verify
    forward, k+1 cache reads, expected rollback, all divided by τ —
    must fit the same per-token (T0, E0)."""
    t0, e0 = budgets
    sol = codesign.solve_speculative(lam, lam_kv, _P, t0, e0)
    if sol is None:
        return
    tau = sol.tokens_per_round
    d = float(cost_model.speculative_round_delay(
        sol.b_hat, sol.f, sol.f_server, sol.b_draft, sol.k, tau, _P,
        b_kv=sol.b_kv)) / tau
    e = float(cost_model.speculative_round_energy(
        sol.b_hat, sol.f, sol.f_server, sol.b_draft, sol.k, tau, _P,
        b_kv=sol.b_kv)) / tau
    assert sol.feasible
    assert d == pytest.approx(sol.delay, rel=1e-9)
    assert e == pytest.approx(sol.energy, rel=1e-9)
    assert d <= t0 * (1 + 1e-6) and e <= e0 * (1 + 1e-6)
    assert 1.0 <= tau <= sol.k + 1
    assert 0.0 <= sol.alpha <= 1.0


@settings(max_examples=40, deadline=None)
@given(lam=_LAM, lam_kv=_LAM, budgets=_BUDGETS,
       slack=st.tuples(st.floats(min_value=0.0, max_value=4.0),
                       st.floats(min_value=0.0, max_value=4.0)))
def test_loosening_budgets_never_increases_decode_bound(lam, lam_kv,
                                                        budgets, slack):
    """More (T0, E0) slack can only help: the feasible set grows, so
    the minimized joint distortion bound is monotone non-increasing."""
    t0, e0 = budgets
    tight = codesign.solve_decode(lam, lam_kv, _P, t0, e0)
    if tight is None:
        return
    loose = codesign.solve_decode(lam, lam_kv, _P, t0 + slack[0],
                                  e0 + slack[1])
    assert loose is not None
    assert loose.objective <= tight.objective + 1e-9


@settings(max_examples=25, deadline=None)
@given(lam=_LAM, lam_kv=_LAM, budgets=_BUDGETS,
       slack=st.tuples(st.floats(min_value=0.0, max_value=4.0),
                       st.floats(min_value=0.0, max_value=4.0)))
def test_loosening_budgets_never_increases_spec_bound(lam, lam_kv,
                                                      budgets, slack):
    t0, e0 = budgets
    tight = codesign.solve_speculative(lam, lam_kv, _P, t0, e0)
    if tight is None:
        return
    loose = codesign.solve_speculative(lam, lam_kv, _P, t0 + slack[0],
                                       e0 + slack[1])
    assert loose is not None
    assert loose.objective <= tight.objective + 1e-9


@settings(max_examples=100, deadline=None)
@given(d1=st.floats(min_value=0.0, max_value=50.0),
       d2=st.floats(min_value=0.0, max_value=50.0),
       gamma=st.floats(min_value=0.1, max_value=10.0))
def test_acceptance_in_unit_interval_and_monotone(d1, d2, gamma):
    """The §16 acceptance estimator is a probability and degrades (never
    improves) as the draft's distortion bound grows."""
    lo, hi = sorted((d1, d2))
    a_lo = codesign.acceptance_from_distortion(lo, gamma)
    a_hi = codesign.acceptance_from_distortion(hi, gamma)
    assert 0.0 <= a_hi <= a_lo <= 1.0


@settings(max_examples=100, deadline=None)
@given(lam=_LAM, gamma=st.floats(min_value=0.1, max_value=10.0))
def test_acceptance_monotone_in_draft_bits(lam, gamma):
    """More draft fidelity never lowers modeled acceptance — the shape
    the benchmark checks against *measured* acceptance."""
    rates = [codesign.acceptance_rate(b, lam, gamma) for b in (2, 4, 8)]
    assert all(0.0 <= r <= 1.0 for r in rates)
    assert rates == sorted(rates)


@settings(max_examples=100, deadline=None)
@given(a1=st.floats(min_value=0.0, max_value=1.0),
       a2=st.floats(min_value=0.0, max_value=1.0),
       k=st.integers(min_value=1, max_value=16))
def test_expected_tokens_per_round_bounds(a1, a2, k):
    """τ(α, k) = Σ_{i=0..k} αⁱ ∈ [1, k+1], monotone in both acceptance
    and lookahead — the engine's billing divides by it, so these bounds
    keep every per-token cost finite and positive."""
    lo, hi = sorted((a1, a2))
    t_lo = codesign.expected_tokens_per_round(lo, k)
    t_hi = codesign.expected_tokens_per_round(hi, k)
    assert 1.0 <= t_lo <= t_hi <= k + 1
    assert t_hi <= codesign.expected_tokens_per_round(hi, k + 1)


def test_codesign_contract_spot_checks():
    """Deterministic floor for the solver-contract properties, exercised
    even without hypothesis installed."""
    sol = codesign.solve_decode(1.0, 1.0, _P, 2.0, 2.0)
    assert sol is not None and sol.feasible
    assert float(cost_model.total_delay(
        sol.b_hat, sol.f, sol.f_server, _P, b_kv=sol.b_kv)) <= 2.0 * (1 + 1e-6)
    spec = codesign.solve_speculative(1.0, 1.0, _P, 2.0, 2.0)
    assert spec is not None and spec.feasible
    # the joint draft variables must pay for themselves: strictly lower
    # distortion bound per expected delivered token
    assert spec.objective < sol.objective
    assert codesign.expected_tokens_per_round(0.0, 4) == 1.0
    assert codesign.expected_tokens_per_round(1.0, 4) == 5.0


def test_kv_quantize_spot_checks():
    assert kv_levels(4) == 7 and kv_levels(8) == 127
    x = np.array([[1.0, -2.0, 0.5, 2.0]], np.float32)
    codes, scales = kv_quantize(x, 8)
    assert float(np.asarray(scales)[0]) == pytest.approx(2.0 / 127)
    np.testing.assert_allclose(np.asarray(kv_dequantize(codes, scales)),
                               x, atol=2.0 / 127 / 2 + 1e-7)
    # container accounting matches the wire format: packed int4, int8,
    # raw float above the ladder
    shape = (2, 3, 4, 5, 8)
    n = int(np.prod(shape))
    n_vec = n // shape[-1]
    assert kv_cache_bytes(shape, 4) == (n + 1) // 2 + 4 * n_vec
    assert kv_cache_bytes(shape, 8) == n + 4 * n_vec
    assert kv_cache_bytes(shape, 16) == 2 * n
