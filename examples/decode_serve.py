"""Continuous-batching greedy decode over a quantized KV cache — the
decode loop of DESIGN.md §12, end to end.

One ragged stream of prompts (staggered arrivals, per-request generation
budgets) is decoded twice through the *same* compiled step functions:

  * barrier    — the FIFO baseline: a slot block admits a full batch,
                 then no new request enters until every member has
                 retired, so late arrivals wait out the longest request.
  * continuous — requests admit into any free slot between decode
                 rounds and retire independently; the batch stays full
                 and time-to-first-token stops paying for strangers.

Each QoS class decodes under the (b̂, f, f̃, b_kv) operating point the
decode codesign picks — the KV cache is *stored* at b_kv bits
(``kernels.quantize.kv_quantize``) and the cache-read term puts b_kv in
the (T0, E0) feasibility check, so the tight realtime class lands on a
lower rung than the relaxed interactive class.

The punchline: continuous admission beats the barrier on modeled
throughput at identical arithmetic — every response is bitwise-verified
against the non-batched sequential reference (DESIGN.md §12 invariants).

Run:  PYTHONPATH=src python examples/decode_serve.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (CompiledForwardCache, DecodeEngine, QosClass,
                           greedy_decode_reference)

SEQ = 24
MAX_NEW = 8
N_REQUESTS = 10
MAX_BATCH = 3


def make_sysp(cfg):
    """Smoke-scale FLOPs plus a KV-cost term sized so the b_kv rung is a
    real decision (full-precision cache read: 0.5 s / 1 J per step)."""
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    tokens = MAX_BATCH * SEQ
    kv_full = (2.0 * cfg.n_layers * MAX_BATCH * (SEQ + MAX_NEW)
               * cfg.n_kv_heads * cfg.head_dim
               * np.dtype(cfg.dtype).itemsize)
    return SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens,
        kv_bytes_full=kv_full, kv_bw_bps=kv_full, kv_power_w=2.0)


def traffic(cfg, rng):
    # ragged generation budgets are what separates the two policies: a
    # short request retires mid-flight and its slot refills (continuous)
    # or sits empty until the whole block drains (barrier)
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        n_new = int(rng.integers(2, MAX_NEW + 1))
        yield toks, ("realtime", "interactive")[i % 2], 0.05 * i, n_new


def serve(admission, model, params, sysp, classes, compile_cache):
    eng = DecodeEngine(model, params, sysp, classes=classes,
                       max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                       admission=admission, compile_cache=compile_cache)
    eng.warmup(SEQ)
    prompts = {}
    for toks, qos, t, n_new in traffic(model.cfg, np.random.default_rng(7)):
        rid = eng.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)
        prompts[rid] = np.asarray(toks, dtype=np.int32)
    return eng, eng.drain(), prompts


def main():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = make_sysp(cfg)
    classes = [QosClass("realtime", t0=1.2, e0=1.0),
               QosClass("interactive", t0=3.5, e0=2.0)]
    shared = CompiledForwardCache()  # both runs share the compiled steps

    print(f"arch={cfg.name}: {N_REQUESTS} staggered prompts, "
          f"max_batch={MAX_BATCH}, {MAX_NEW} new tokens each\n")
    results = {}
    for admission in ("barrier", "continuous"):
        eng, responses, prompts = serve(admission, model, params, sysp,
                                        classes, shared)
        rep = eng.report()
        results[admission] = rep
        print(f"admission={admission}:")
        for cs in rep.classes:
            print(f"  [{cs.qos:12s}] n={cs.requests} b̂={cs.b_hat} "
                  f"b_kv={cs.b_kv} ttft={cs.ttft_mean_s * 1e3:7.1f}ms "
                  f"(max {cs.ttft_max_s * 1e3:7.1f}ms) "
                  f"itl={cs.itl_mean_s * 1e3:6.1f}ms")
        ratio = rep.kv_bytes / rep.kv_bytes_full if rep.kv_bytes_full \
            else 1.0
        print(f"  -> {rep.tokens_generated} tokens in "
              f"{rep.decode_rounds} rounds, "
              f"{rep.throughput_tps:.1f} tok/s (modeled), "
              f"kv cache {ratio:.2f}x of full precision")

        # every response is bitwise-checked against the sequential
        # reference decoding the same prompt alone (DESIGN.md §12)
        for r in responses:
            ref = greedy_decode_reference(
                model, eng.class_params(r.qos), prompts[r.request_id],
                len(r.tokens), b_kv=r.b_kv, compile_cache=shared)
            assert np.array_equal(np.asarray(r.tokens), ref), r.request_id
        print(f"  -> all {len(responses)} responses bitwise-match the "
              "non-batched reference\n")

    speedup = results["continuous"].throughput_tps \
        / results["barrier"].throughput_tps
    print(f"continuous admission: {speedup:.2f}x the barrier's modeled "
          "throughput on the same stream, same compiled step functions, "
          "token-for-token identical output — batching is a scheduling "
          "decision, not a numerics decision (DESIGN.md §12).")


if __name__ == "__main__":
    main()
