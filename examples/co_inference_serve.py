"""Batched co-inference serving with per-QoS-class co-design — the paper's
system loop end to end, through the batched engine (DESIGN.md §7).

Three QoS classes (realtime / interactive / batch) each get their own
(b̂, f, f̃) from Algorithm 1 — solved once per class via the codesign
cache, not once per request.  A mixed-traffic queue is drained into
per-class batches: each batch runs the actual quantized agent -> uplink ->
server pipeline (Pallas quantized-matmul path for the agent stage), with
per-class delay/energy accounting from the paper's cost model and
batch-level occupancy/queue-wait stats.  A full-precision engine measures
the realized output distortion per class.

Run:  PYTHONPATH=src python examples/co_inference_serve.py
      PYTHONPATH=src python examples/co_inference_serve.py --mixed-precision

With ``--mixed-precision`` each class gets a *per-layer* bit allocation
(core/mixed_precision.py, DESIGN.md §8) instead of one uniform b̂: the
allocator spends the same delay/energy budget where the chain-bound
sensitivities say it buys the most distortion reduction, and each agent
layer runs the kernel container its bits admit (int4-packed / int8 /
fp16 fallback).
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CodesignCache,
                           CoInferenceEngine, QosClass)

# (T0, E0) chosen so the classes land on b_hat = 4 / 8 / 16: the two
# tight classes really exercise the int4/int8 Pallas kernel path, the
# loose one runs effectively unquantized (b_hat=16 -> fake path)
CLASSES = [
    QosClass("realtime", t0=1.15, e0=0.95),
    QosClass("interactive", t0=1.30, e0=1.25),
    QosClass("batch", t0=2.50, e0=4.0),
]
SEQ = 32
N_REQUESTS = 24


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mixed-precision", action="store_true",
                    help="per-layer bit allocation per class "
                         "(DESIGN.md §8) instead of one uniform b̂")
    ap.add_argument("--compiled", action="store_true",
                    help="serve through the compiled fast path "
                         "(DESIGN.md §10): bucket-padded AOT executables, "
                         "precompiled by warmup(), bitwise identical "
                         "per request to eager serving")
    args = ap.parse_args()

    cfg = get_smoke("stablelm-3b")
    if args.mixed_precision:
        # widen the agent partition (smoke default is a single layer) so
        # the allocator has layers to trade bits between
        cfg = dataclasses.replace(cfg, split_layer=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)

    # kernel path: for classes whose b̂ lands on 4 or 8 the agent weights
    # are actually int4/int8-resident via the Pallas quantized matmul
    # (interpret mode on CPU); other bit-widths fall back to fake
    # quantization — each batch below prints which path really ran.  One
    # engine serves all classes, re-materializing weights only on an
    # operating point it has not seen yet (weight cache keyed on the
    # stable plan hash)
    cache = CodesignCache()
    eng = BatchedCoInferenceEngine(model, params, sysp, classes=CLASSES,
                                   max_batch=8, path="kernel",
                                   codesign_cache=cache,
                                   mixed_precision=args.mixed_precision,
                                   compiled=args.compiled)
    if args.compiled:
        print(f"warmup: {eng.warmup(SEQ)} compiled forward variants")
    clean = CoInferenceEngine(model, params, sysp)
    clean.configure(16)
    clean.b_emb = 16

    print(f"{'class':13s} {'bits':>12s} {'f GHz':>6s} {'f~ GHz':>6s} "
          f"{'T (model)':>10s} {'E (model)':>10s}")
    for qos in CLASSES:
        s = eng.solution_for(qos.name)
        bdesc = "/".join(map(str, s.bits)) if args.mixed_precision \
            else str(s.b_hat)
        print(f"{qos.name:13s} {bdesc:>12s} {s.f / 1e9:6.2f} "
              f"{s.f_server / 1e9:6.2f} {s.delay:9.3f}s {s.energy:9.3f}J")

    # mixed traffic: round-robin classes, ragged lengths
    rng = np.random.default_rng(0)
    sent = {}
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        sent[eng.submit(toks, CLASSES[i % len(CLASSES)].name)] = toks

    responses = eng.drain()

    # realized distortion per class vs the clean full-precision engine
    dist = {c.name: 0.0 for c in CLASSES}
    count = {c.name: 0 for c in CLASSES}
    for r in responses:
        toks = jnp.asarray(sent[r.request_id], jnp.int32)[None]
        ref, _ = clean.serve_batch({"tokens": toks})
        dist[r.stats.qos] += float(jnp.sum(jnp.abs(r.logits - ref[0])))
        count[r.stats.qos] += 1

    print(f"\nserved {len(responses)} requests in "
          f"{len(eng.batch_history)} single-class batches:")
    for b in eng.batch_history:
        bdesc = "/".join(map(str, b.plan_bits)) if b.plan_bits \
            else f"{b.b_hat:2d}"
        print(f"  [{b.qos:12s}] n={b.batch_size} b_hat={bdesc} "
              f"({b.agent_path}) occupancy={b.occupancy:.2f} "
              f"amortized T={b.amortized_delay_s * 1e3:7.2f}ms/req "
              f"E={b.amortized_energy_j:.4f}J/req "
              f"uplink={b.emb_bytes / 1024:.1f}KiB")

    print(f"\n{'class':13s} {'requests':>8s} {'distortion':>11s}")
    for c in CLASSES:
        print(f"{c.name:13s} {count[c.name]:8d} "
              f"{dist[c.name] / max(count[c.name], 1):11.1f}")

    rep = eng.report()
    print(f"\nreport: mean_batch={rep.mean_batch_size:.2f} "
          f"occupancy={rep.mean_occupancy:.2f} "
          f"modeled throughput={rep.throughput_rps:.0f} req/s; "
          f"codesign cache: {rep.codesign_misses} solves, "
          f"{rep.codesign_hits} hits")
    if args.compiled:
        print(f"compile cache: {rep.compiled_variants} variants, "
              f"{rep.compile_hits} hits / {rep.compile_misses} misses")
    print("\ntighter QoS -> smaller b_hat -> more distortion; batching "
          "amortizes delay/energy across a class without ever mixing "
          "classes in one forward — the paper's quality/latency/energy "
          "triangle, served at queue scale.")


if __name__ == "__main__":
    main()
