"""Co-inference serving with per-QoS-class co-design — the paper's system
loop, end to end, with batched requests.

Three QoS classes (realtime / interactive / batch) each get their own
(b̂, f, f̃) from Algorithm 1; requests are served through the actual
quantized agent -> uplink -> server pipeline, including the Pallas
quantized-matmul path for the agent stage, and per-class delay/energy
accounting from the paper's cost model.

Run:  PYTHONPATH=src python examples/co_inference_serve.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.data import MarkovLMConfig, MarkovLMDataset
from repro.models.registry import build_model
from repro.runtime import CoInferenceEngine, QosClass

CLASSES = [
    QosClass("realtime", t0=1.10, e0=0.9),
    QosClass("interactive", t0=1.30, e0=1.5),
    QosClass("batch", t0=2.50, e0=4.0),
]


def main():
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)

    ds = MarkovLMDataset(MarkovLMConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, batch_size=4))
    clean_engine = CoInferenceEngine(model, params, sysp)
    clean_engine.configure(16)
    clean_engine.b_emb = 16

    print(f"{'class':13s} {'b_hat':>5s} {'f GHz':>6s} {'f~ GHz':>6s} "
          f"{'T (model)':>10s} {'E (model)':>10s} {'distortion':>11s} "
          f"{'uplink':>9s}")
    for qos in CLASSES:
        # kernel path: agent weights actually int8/int4-resident via the
        # Pallas quantized matmul (interpret mode on CPU)
        eng = CoInferenceEngine(model, params, sysp, path="kernel")
        sol = eng.auto_configure(qos)
        if sol is None:
            print(f"{qos.name:13s}  -- infeasible under "
                  f"(T0={qos.t0}, E0={qos.e0})")
            continue
        served = 0
        dist = 0.0
        emb_bytes = 0
        for step in range(3):  # three request batches per class
            batch = {"tokens": jnp.asarray(ds.batch_at(step)["tokens"])}
            logits, stats = eng.serve_batch(batch)
            clean, _ = clean_engine.serve_batch(batch)
            dist += float(jnp.sum(jnp.abs(logits - clean)))
            emb_bytes += stats.emb_bytes
            served += batch["tokens"].shape[0]
        print(f"{qos.name:13s} {sol.b_hat:5d} {sol.f / 1e9:6.2f} "
              f"{sol.f_server / 1e9:6.2f} {sol.delay:9.3f}s "
              f"{sol.energy:9.3f}J {dist / served:11.1f} "
              f"{emb_bytes / 3 / 1024:7.1f}KiB")

    print("\ntighter QoS -> smaller b_hat -> more distortion; the uplink "
          "bytes track b_emb — the paper's quality/latency/energy triangle.")


if __name__ == "__main__":
    main()
