"""Fault-tolerant training demo: inject host failures mid-run, watch the
supervisor restore from checkpoint, re-mesh over the survivors and finish.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import tempfile

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import AdamW
from repro.runtime import (HostSet, StragglerMonitor, Supervisor,
                           TrainConfig, Trainer)


class Session:
    def __init__(self, ckpt_dir, n_hosts):
        cfg = get_smoke("qwen2-0.5b")
        self.tr = Trainer(build_model(cfg), AdamW(learning_rate=2e-3),
                          make_host_mesh(), TrainConfig(log_every=100),
                          ckpt=CheckpointManager(ckpt_dir, save_interval=5))
        self.loader = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)))
        self.n_hosts = n_hosts
        print(f"  [session] built over {n_hosts} hosts")

    @property
    def step(self):
        return self.tr.step

    def run_until(self, target, hosts):
        params, opt, err = self.tr.init_state(jax.random.PRNGKey(0))
        params, opt, err, start = self.tr.maybe_restore(params, opt, err)
        if start:
            print(f"  [session] restored checkpoint at step {start}")
        self.loader.seek(start)
        self.tr.build_step(self.loader.peek_structure())
        state = (params, opt, err)
        while self.tr.step < target:
            hosts.check(self.tr.step)
            state, hist = self.tr.fit(self.loader, 1, state=state)
            if self.tr.step % 5 == 0:
                print(f"  step {self.tr.step:3d}  "
                      f"loss {hist[-1]['loss']:.4f}")
                self.tr.ckpt.save(self.tr.step,
                                  {"params": state[0], "opt": state[1],
                                   "err": state[2]},
                                  metadata={"data_step": self.tr.step})


def main():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=8, fail_at={12: 5, 23: 2})
        print("cluster: 8 hosts; failures injected at steps 12 and 23\n")
        sup = Supervisor(lambda n: Session(d, n), hosts,
                         monitor=StragglerMonitor(factor=3.0))
        report = sup.run(target_steps=30)
        print(f"\nfinished at step {report.final_step}: "
              f"{report.restarts} restarts after losing hosts "
              f"{report.failures}; mesh sizes {report.remesh_history}")


if __name__ == "__main__":
    main()
