"""Quickstart: the paper's pipeline end-to-end in ~60 seconds on CPU.

  1. fit the exponential weight prior (paper eq. 3) on a real model,
  2. evaluate the distortion-rate bounds (Props 4.1/4.2),
  3. jointly pick (b̂, f, f̃) under a QoS target (Algorithm 1),
  4. serve a batch through the quantized agent/server split and compare the
     realized output distortion across bit-widths.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import codesign as cd
from repro.core.cost_model import SystemParams
from repro.core.rate_distortion import (distortion_lower_bound,
                                        distortion_upper_bound,
                                        exponential_mle)
from repro.models.registry import build_model
from repro.runtime import CoInferenceEngine, QosClass


def main():
    # -- a real (reduced) model -------------------------------------------
    cfg = get_smoke("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  layers={cfg.n_layers}  "
          f"split at {cfg.split_layer} (agent|server)")

    # -- 1. weight statistics (paper eq. 3 / Fig. 2) ----------------------
    mags = jnp.concatenate([jnp.abs(l).ravel() for l in
                            jax.tree_util.tree_leaves(params)
                            if hasattr(l, 'ndim') and l.ndim >= 2])
    lam = float(exponential_mle(mags))
    print(f"\n[1] exponential fit: lambda_hat = {lam:.1f}")

    # -- 2. rate-distortion interval (Props 4.1 / 4.2) --------------------
    print("\n[2] distortion-rate interval per bit-width (rate = b-1):")
    for b in (2, 4, 6, 8):
        dl = float(distortion_lower_bound(b - 1, lam))
        du = float(distortion_upper_bound(b - 1, lam))
        print(f"    b={b}:  D in [{dl:.2e}, {du:.2e}]")

    # -- 3. joint co-design (Algorithm 1) ---------------------------------
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
    sol = cd.solve_sca(lam, sysp, t0=1.3, e0=1.5)
    print(f"\n[3] Algorithm 1 under (T0=1.3s, E0=1.5J): b_hat={sol.b_hat}, "
          f"f={sol.f / 1e9:.2f} GHz, f~={sol.f_server / 1e9:.2f} GHz")
    print(f"    realized T={sol.delay:.3f}s E={sol.energy:.3f}J "
          f"({sol.iterations} SCA iterations)")

    # -- 4. quantized co-inference serving --------------------------------
    eng = CoInferenceEngine(model, params, sysp, lam=lam)
    eng.b_emb = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    clean, _ = model.forward(params, {"tokens": toks})
    print("\n[4] measured output distortion through the split:")
    for b in (16, 8, 4, 2):
        eng.configure(b)
        logits, stats = eng.serve_batch({"tokens": toks})
        d = float(jnp.sum(jnp.abs(logits - clean)) / toks.shape[0])
        print(f"    b_hat={b:2d}: ||f - f_hat||_1 = {d:9.2f}   "
              f"T={stats.total_delay_s * 1e3:7.2f} ms  "
              f"E={stats.energy_j:6.3f} J")
    eng.auto_configure(QosClass("interactive", t0=1.3, e0=1.5))
    print(f"\n    auto-configured to b_hat={eng.b_hat} for the QoS class")


if __name__ == "__main__":
    main()
