"""End-to-end training driver: ~100M-parameter LM, quantization-aware, with
checkpointing — a few hundred steps on CPU with visibly decreasing loss.

The model is the qwen2 family at ~100M scale (12L x 768), trained on the
deterministic Markov-chain corpus with QAT on the agent partition (the
co-inference split it will be served at), int8 error-feedback gradient
compression enabled, and async checkpoints every 50 steps.  Kill it and
re-run: it resumes from the newest checkpoint at the exact data step.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import AdamW, cosine_schedule
from repro.runtime import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # qwen2 family at ~100M: 12 x 768, GQA kv=4, vocab 32k
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"), name="qwen2-100m",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32768, split_layer=3)
    model = build_model(cfg)
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"QAT bits=8 on layers [0, {cfg.split_layer})")

    ds = MarkovLMDataset(MarkovLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch, branching=4))
    loader = ShardedLoader(ds)

    trainer = Trainer(
        model,
        AdamW(learning_rate=cosine_schedule(3e-4, 30, args.steps)),
        make_host_mesh(),
        TrainConfig(qat_bits=8, grad_compression="int8_ef", log_every=20),
        ckpt=CheckpointManager(args.ckpt_dir, save_interval=50, keep=2))

    _, hist = trainer.fit(
        loader, args.steps,
        on_metrics=lambda m: print(
            f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
            f"gnorm {m['grad_norm']:.2f}  {m['steps_per_s']:.2f} it/s"))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    # Markov chain with branching 4 -> optimal loss = ln(4) ~ 1.386
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"(entropy floor ~1.386); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
