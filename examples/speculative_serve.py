"""Speculative co-inference: the agent drafts at b_draft bits, the
server verifies — the round model of DESIGN.md §16, end to end.

One ragged stream of prompts (staggered arrivals, per-request generation
budgets) is decoded twice:

  * decode      — PR-6 continuous batching: one greedy target token per
                  round, every round pays the full (b̂, f, f̃) forward.
  * speculative — the agent partition fake-quantized at b_draft greedily
                  drafts k tokens per round; the server partition
                  verifies all k in one batched forward and keeps the
                  longest accepted prefix plus one correction token.

Acceptance is a numerics property: the draft head *is* the target model
squeezed through a b_draft-bit container, so the acceptance rate falls
out of the same distortion bound D^U(b_draft) the codesign already
trusts — α = exp(−γ·λ·D^U) — and (b_draft, k, f) become joint variables
in P1, minimizing the bound per *expected delivered token* under the
same (T0, E0) budgets.

The punchline: rounds shrink by the accepted-prefix length while every
delivered stream stays bitwise identical to the sequential reference —
drafts decide how many verify iterations run, never which bits are
committed (commit-on-verify, DESIGN.md §16).

Run:  PYTHONPATH=src python examples/speculative_serve.py
"""

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (CompiledForwardCache, DecodeEngine, QosClass,
                           SpeculativeDecodeEngine, greedy_decode_reference)

SEQ = 24
MAX_NEW = 8
N_REQUESTS = 10
MAX_BATCH = 3


def make_sysp(cfg):
    """Smoke-scale FLOPs plus a KV-cost term sized so b_kv is a real
    decision.  The cache stream gets 2x the decode example's bandwidth:
    a speculative round moves (k+1) cache streams where plain decode
    moves one, so the single-stream choke would starve every (b_kv,
    b_draft, k) point before the draft/verify trade-off even appears."""
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    tokens = MAX_BATCH * SEQ
    kv_full = (2.0 * cfg.n_layers * MAX_BATCH * (SEQ + MAX_NEW)
               * cfg.n_kv_heads * cfg.head_dim
               * np.dtype(cfg.dtype).itemsize)
    return SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens,
        kv_bytes_full=kv_full, kv_bw_bps=2.0 * kv_full, kv_power_w=2.0)


def traffic(cfg, rng):
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        n_new = int(rng.integers(2, MAX_NEW + 1))
        yield toks, ("realtime", "interactive")[i % 2], 0.05 * i, n_new


def serve(engine_cls, model, params, sysp, classes, compile_cache):
    eng = engine_cls(model, params, sysp, classes=classes,
                     max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                     compile_cache=compile_cache)
    eng.warmup(SEQ)
    prompts = {}
    for toks, qos, t, n_new in traffic(model.cfg, np.random.default_rng(7)):
        rid = eng.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)
        prompts[rid] = np.asarray(toks, dtype=np.int32)
    return eng, eng.drain(), prompts


def main():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = make_sysp(cfg)
    classes = [QosClass("realtime", t0=1.2, e0=1.0),
               QosClass("interactive", t0=3.5, e0=2.0)]

    print(f"arch={cfg.name}: {N_REQUESTS} staggered prompts, "
          f"max_batch={MAX_BATCH}, {MAX_NEW} new tokens each\n")
    results = {}
    for mode, engine_cls in (("decode", DecodeEngine),
                             ("speculative", SpeculativeDecodeEngine)):
        shared = CompiledForwardCache()
        eng, responses, prompts = serve(engine_cls, model, params, sysp,
                                        classes, shared)
        rep = eng.report()
        results[mode] = rep
        print(f"mode={mode}:")
        for cs in rep.classes:
            line = (f"  [{cs.qos:12s}] n={cs.requests} b̂={cs.b_hat} "
                    f"b_kv={cs.b_kv}")
            if mode == "speculative":
                b_d, k = eng.draft_schedule(cs.qos)
                line += f" b_draft={b_d} k={k}"
            print(line + f" ttft={cs.ttft_mean_s * 1e3:7.1f}ms "
                  f"itl={cs.itl_mean_s * 1e3:6.1f}ms")
        print(f"  -> {rep.tokens_generated} tokens in "
              f"{rep.decode_rounds} rounds, "
              f"{rep.throughput_tps:.1f} tok/s (modeled)")
        if mode == "speculative":
            st = eng.spec_stats()
            print(f"  -> acceptance={st.acceptance_rate:.2f}, "
                  f"accepted/round={st.accepted_per_round:.2f}, "
                  f"tokens/round={st.tokens_per_round:.2f}")

        # the house invariant, extended: drafting changes the schedule,
        # never the bits — every delivered stream is bitwise-checked
        # against the sequential reference (DESIGN.md §16)
        for r in responses:
            ref = greedy_decode_reference(
                model, eng.class_params(r.qos), prompts[r.request_id],
                len(r.tokens), b_kv=r.b_kv, compile_cache=shared)
            assert np.array_equal(np.asarray(r.tokens), ref), r.request_id
        print(f"  -> all {len(responses)} responses bitwise-match the "
              "non-batched reference\n")

    dec, spec = results["decode"], results["speculative"]
    print(f"speculative rounds: {dec.decode_rounds} -> "
          f"{spec.decode_rounds} decode rounds for the same stream "
          f"({dec.decode_rounds / max(spec.decode_rounds, 1):.1f}x fewer "
          "server round-trips), token-for-token identical output — the "
          "draft head only ever proposes; the target model commits "
          "(DESIGN.md §16).")


if __name__ == "__main__":
    main()
