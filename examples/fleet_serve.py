"""Multi-agent fleet co-inference over one shared edge server — the
(P-fleet) allocation of DESIGN.md §11, end to end.

Three heterogeneous embodied agents share a single edge server: a
deadline-tight delivery drone, and two slack monitors over a different
architecture.  The fleet allocator splits the server frequency across
them — the water-filling joint codesign against the equal-split
baseline, both serving the *identical* per-agent request streams
through :class:`FleetCoInferenceEngine` at the same per-agent (T0, E0)
budgets — and the realized output distortion is measured against a
full-precision reference per agent.

The point the numbers make: under an equal split the tight agent's
small server slice forces it to a coarse bit-width; the joint allocator
shrinks the slack agents to their feasibility thresholds (their b̂ = 16
survives) and hands the freed share to the tight agent, whose b̂ — and
measured distortion — improves at matched budgets.

Run:  PYTHONPATH=src python examples/fleet_serve.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (CoInferenceEngine, FleetAgentSpec,
                           FleetCoInferenceEngine, QosClass)

SEQ = 24
N_REQUESTS = 6
MAX_BATCH = 2
# calibrated decision-scale workload (DESIGN.md §7): the server term is
# a real fraction of the tight deadline, so the share split has teeth
SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)

AGENTS = [
    # (name, arch, T0, E0, weight)
    ("drone", "qwen2-0.5b", 0.8, 8.0, 1.0),
    ("monitor-a", "stablelm-3b", 3.0, 4.0, 1.0),
    ("monitor-b", "stablelm-3b", 3.0, 4.0, 1.0),
]


def main():
    models = {}
    specs = []
    for name, arch, t0, e0, weight in AGENTS:
        if arch not in models:
            cfg = get_smoke(arch)
            model = build_model(cfg)
            models[arch] = (model, model.init(jax.random.PRNGKey(0)))
        model, params = models[arch]
        specs.append(FleetAgentSpec(
            name=name, model=model, params=params, sysp=SYSP,
            qos=QosClass(name, t0=t0, e0=e0), weight=weight))

    # identical per-agent streams for both allocators
    rng = np.random.default_rng(4)
    streams = {
        s.name: [rng.integers(0, s.model.cfg.vocab_size,
                              size=int(rng.integers(SEQ // 2, SEQ + 1)))
                 for _ in range(N_REQUESTS)]
        for s in specs}

    # full-precision references (one clean engine per architecture)
    refs, clean = {}, {}
    for s in specs:
        if id(s.model) not in clean:
            eng = CoInferenceEngine(s.model, s.params, SYSP, b_emb=16)
            eng.configure(16)
            clean[id(s.model)] = eng
        refs[s.name] = [
            clean[id(s.model)].serve_batch(
                {"tokens": jnp.asarray(t, jnp.int32)[None]})[0][0]
            for t in streams[s.name]]

    for allocator in ("equal", "joint"):
        fleet = FleetCoInferenceEngine(specs, allocator=allocator,
                                       max_batch=MAX_BATCH)
        for s in specs:
            for toks in streams[s.name]:
                fleet.submit(s.name, toks)
        responses = fleet.drain()
        rep = fleet.report()

        print(f"\nallocator={allocator}  aggregate bound="
              f"{rep.aggregate_bound:.4e}")
        print(f"{'agent':12s} {'share':>6s} {'b_hat':>5s} {'bound':>10s} "
              f"{'distortion':>10s} {'occup':>6s}")
        for s, pa in zip(specs, rep.per_agent):
            by_id = {r.request_id: r for r in responses[s.name]}
            dist = sum(float(jnp.sum(jnp.abs(by_id[i].logits
                                             - refs[s.name][i])))
                       for i in range(N_REQUESTS)) / N_REQUESTS
            print(f"{pa.name:12s} {pa.share:6.3f} {pa.b_hat:5d} "
                  f"{pa.bound:10.3e} {dist:10.2f} "
                  f"{pa.mean_occupancy:6.2f}")
        print(f"shared codesign cache: {rep.codesign_misses} solves / "
              f"{rep.codesign_hits} hits across {rep.n_agents} agents")

    print("\nsame budgets, same streams — only the server split differs: "
          "the joint allocator buys the deadline-tight agent a finer "
          "bit-width with share the slack agents never needed.")


if __name__ == "__main__":
    main()
