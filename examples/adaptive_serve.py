"""Adaptive co-inference serving under a changing environment — the
closed loop of DESIGN.md §9, end to end.

A thermal throttle replays the paper's Table I coarse frequency profiles
(high -> low -> high) while a Markov-chain Wi-Fi uplink fades and
recovers.  The same request stream is served twice:

  * static   — the paper's one-shot (P1) co-design, solved for the
               initial state and never revisited; when the device
               throttles, its plan silently runs slow and misses
               deadlines.
  * adaptive — ``AdaptiveCoInferenceEngine`` watches the (quantized)
               environment state and realized per-batch QoS, re-solves
               (P1) through the environment-keyed codesign cache after a
               sustained change, and degrades gracefully in windows
               where no plan can meet the class at all.

Everything is calibrated to the *smoke* model's realized workload
(DESIGN.md §7): the engine bills batches at the model's actual FLOPs,
so the QoS deadline and the trace's dwell times live at that scale —
the control loop is scale-free.

Run:  PYTHONPATH=src python examples/adaptive_serve.py

The punchline printed at the end: same model, same requests, same
physics — the adaptive controller trades a few bits of precision during
the throttled window for a deadline-violation rate far below the static
plan's, with a bounded, reported number of replans.
"""

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.env import Environment, MarkovLink, TraceReplay
from repro.models.registry import build_model
from repro.runtime import (AdaptiveCoInferenceEngine, CoInferenceEngine,
                           QosClass)

SEQ = 32
N_REQUESTS = 24
HORIZON_S = 36.0e-3     # smoke-workload timescale: one request ~0.05 ms


def smoke_scale(model):
    """Per-request SystemParams + a deadline sized to the smoke model."""
    probe = CoInferenceEngine(model, model.init(jax.random.PRNGKey(9)),
                              SystemParams(n_flop_agent=1.0,
                                           n_flop_server=1.0))
    n_a, n_s = probe.flop_split(SEQ)
    sysp = SystemParams(n_flop_agent=n_a, n_flop_server=n_s,
                        emb_bytes_full=float(SEQ * model.cfg.d_model * 2),
                        link_bps=2.0e8, tx_power_w=0.25)
    # deadline: ~78% of the full-precision, full-frequency request time —
    # tight enough that the throttled window forces bits off the plan
    t_ref = n_a / (sysp.c_agent * sysp.f_max) \
        + n_s / (sysp.c_server * sysp.f_server_max)
    return sysp, QosClass("interactive", t0=0.78 * t_ref, e0=2.0e-3)


def build_env():
    """f_max 2.0 -> 0.6 -> 2.0 GHz (Table I profiles), Wi-Fi fading."""
    return Environment(
        dt_s=1.0e-3, horizon_s=HORIZON_S, seed=0,
        f_cap=TraceReplay(values=(2.0e9, 0.6e9, 2.0e9),
                          dwell_s=HORIZON_S / 3.0),
        link=MarkovLink(rates_bps=(2.0e8, 4.0e7),
                        transition=((0.95, 0.05), (0.10, 0.90))))


def serve(policy: str, model, params, sysp, qos):
    eng = AdaptiveCoInferenceEngine(
        model, params, sysp, classes=[qos], max_batch=4,
        environment=build_env(), policy=policy, hysteresis_steps=2)
    rng = np.random.default_rng(3)
    for i in range(N_REQUESTS):
        toks = rng.integers(0, model.cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        eng.submit(toks, qos.name, arrival_s=i * HORIZON_S / N_REQUESTS)
    eng.drain()
    return eng


def main():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp, qos = smoke_scale(model)
    print(f"arch={cfg.name}; trace: f_max 2.0 -> 0.6 -> 2.0 GHz "
          f"(Table I profiles), Wi-Fi 2e8 <-> 4e7 B/s (Markov); "
          f"T0={qos.t0 * 1e6:.1f}us E0={qos.e0 * 1e3:.1f}mJ\n")

    for policy in ("static", "adaptive"):
        eng = serve(policy, model, params, sysp, qos)
        rep = eng.adaptive_report()
        print(f"policy={policy}:")
        line = []
        for b in eng.batch_history:
            # same accounting as the violation counter: worst member's
            # queue wait + the batch's forward delay against T0
            viol = b.queue_wait_max_s + b.batch_delay_s > qos.t0
            line.append(f"b̂={b.b_hat:2d}@{b.f / 1e9:.1f}GHz"
                        + ("!" if viol else " "))
        for lo in range(0, len(line), 6):
            print("   " + "  ".join(line[lo:lo + 6]))
        print(f"  -> violations {rep.deadline_violations}/"
              f"{rep.requests_served}, replans {rep.replans} "
              f"(plan switches {rep.plan_switches}), "
              f"degraded batches {rep.degraded_batches}")
        for e in eng.replan_events:
            print(f"     t={e.t_s * 1e3:5.1f}ms {e.reason}: b̂ "
                  f"{e.b_before:.0f} -> {e.b_after:.0f}"
                  + (" (degraded)" if e.degraded else ""))
        print()

    print("same requests, same physics ('!' marks a missed deadline): "
          "the static plan rides the throttled window at full width and "
          "misses deadlines; the adaptive controller sheds bits while "
          "the device is hot and takes them back when it cools.")


if __name__ == "__main__":
    main()
